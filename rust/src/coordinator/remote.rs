//! Multi-process distributed forward: the master drives P remote workers
//! over TCP (`prism worker --listen ...`), relaying the Segment-Means
//! exchange. Physically meshed edge devices would exchange peer-to-peer;
//! the relay preserves every payload size, so the byte accounting (what
//! the paper's comm columns measure) is identical.

use anyhow::{Context, Result};

use super::plan::plans;
use super::runner::{bias_for, Mode};
use super::segmeans::segment_means;
use crate::net::tcp::{ExecRequest, RemoteWorker};
use crate::runtime::{Manifest, Tensor};

/// Coordinator over TCP workers. Embed/head run wherever the caller's
/// local engine lives; this drives the per-layer block protocol.
pub struct RemoteCoordinator {
    pub workers: Vec<RemoteWorker>,
    pub manifest: std::sync::Arc<Manifest>,
    pub flavor: String,
}

impl RemoteCoordinator {
    pub fn connect(manifest: std::sync::Arc<Manifest>, addrs: &[String],
                   flavor: &str) -> Result<RemoteCoordinator> {
        let workers = addrs
            .iter()
            .map(|a| RemoteWorker::connect(a))
            .collect::<Result<Vec<_>>>()?;
        Ok(RemoteCoordinator {
            workers,
            manifest,
            flavor: flavor.to_string(),
        })
    }

    /// Distributed PRISM/Voltage blocks over the remote workers.
    /// `x` is the embedded (B, N, D) batch; returns the re-assembled
    /// output.
    pub fn blocks(&mut self, model: &str, weights_tag: &str, x: &Tensor,
                  mode: Mode) -> Result<Tensor> {
        let cfg = self.manifest.model(model)?.clone();
        let p = mode.p();
        anyhow::ensure!(self.workers.len() >= p,
                        "need {p} workers, have {}", self.workers.len());
        let l = mode.l();
        let batch = x.shape[0];
        let pls = plans(cfg.n, p, l, cfg.causal)?;
        let duplicated =
            !matches!(mode, Mode::Prism { duplicated: false, .. });
        let biases: Vec<Tensor> = pls
            .iter()
            .map(|pl| bias_for(pl, duplicated))
            .collect::<Result<_>>()?;
        let execs: Vec<String> = (0..p)
            .map(|i| {
                self.manifest.block_name(model, mode.name(), p, l, i,
                                         batch, &self.flavor)
            })
            .collect();
        let mut parts: Vec<Tensor> = pls
            .iter()
            .map(|pl| x.slice1(pl.start(), pl.start() + pl.n_p()))
            .collect::<Result<_>>()?;
        // shares[j]: what device j currently contributes to peers' K/V
        let mut shares: Vec<Tensor> = if l > 0 {
            parts
                .iter()
                .map(|t| segment_means(t, l))
                .collect::<Result<_>>()?
        } else {
            parts.clone()
        };
        for layer in 0..cfg.layers {
            let mut outs = Vec::with_capacity(p);
            let mut new_shares = Vec::with_capacity(p);
            for (i, pl) in pls.iter().enumerate() {
                let peer_shares: Vec<&Tensor> =
                    pl.peers().into_iter().map(|j| &shares[j]).collect();
                let ctx = Tensor::concat1(&peer_shares)?;
                let mut out = self.workers[i]
                    .call(&ExecRequest {
                        exec: execs[i].clone(),
                        weights: weights_tag.to_string(),
                        layer: layer as u32,
                        args: vec![parts[i].clone(), ctx,
                                   biases[i].clone()],
                    })
                    .with_context(|| format!("worker {i} layer {layer}"))?;
                let x_out = out.remove(0);
                let share = if l > 0 {
                    out.remove(0)
                } else {
                    x_out.clone()
                };
                outs.push(x_out);
                new_shares.push(share);
            }
            parts = outs;
            shares = new_shares;
        }
        let refs: Vec<&Tensor> = parts.iter().collect();
        Tensor::concat1(&refs)
    }

    pub fn bytes(&self) -> (usize, usize) {
        self.workers
            .iter()
            .fold((0, 0), |(s, r), w| (s + w.sent_bytes,
                                       r + w.recv_bytes))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        for w in &mut self.workers {
            w.shutdown()?;
        }
        Ok(())
    }
}
