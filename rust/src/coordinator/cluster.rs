//! Elastic membership: the live device set, epoch-versioned partition
//! plans, and the plan cache that lets the serving path swap geometry
//! atomically when devices fail or (re-)join.
//!
//! PRISM's planning (Eq. 16 picks L from N, CR, and P) assumes a fixed
//! device set; the edge reality is that P changes at runtime.
//! [`ClusterView`] owns the membership bitmap and, on `fail_device` /
//! `add_device`, bumps the epoch and re-runs `plan::plans` over the
//! surviving P', re-picking L for the preserved compression target
//! (`plan::replan_l`, the integer-exact form of Eq. 16). Every distinct
//! P' is planned exactly once and cached; an [`EpochPlan`] snapshot is
//! what a serving loop holds while a batch is in flight, so in-flight
//! work drains on its admission-time plan while new work picks up the
//! current one (the epoch tag on the wire protocol keeps the two from
//! mixing — see `net::message::Msg::Reconfig`).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::plan::{clamp_sizes_min, partition_sizes, plans,
                  plans_with_sizes, replan_l, single_plan,
                  weighted_partition_sizes, PartitionPlan};
use super::runner::{degraded_mode, Mode};

/// Immutable snapshot of one epoch's serving geometry.
#[derive(Debug, Clone)]
pub struct EpochPlan {
    /// Monotone transition counter; bumped by every membership change.
    pub epoch: u64,
    /// The strategy re-shaped to the live device count (Eq. 16 L).
    pub mode: Mode,
    /// One plan per *rank*: rank r runs partition r on `devices[r]`.
    pub plans: Arc<Vec<PartitionPlan>>,
    /// Live physical device ids in rank order.
    pub devices: Vec<usize>,
}

impl EpochPlan {
    /// Rank of a physical device in this epoch (None if not serving).
    pub fn rank_of(&self, device: usize) -> Option<usize> {
        self.devices.iter().position(|&d| d == device)
    }

    /// Live device count P' this epoch serves with.
    pub fn p(&self) -> usize {
        self.devices.len()
    }

    /// Per-rank partition widths — the `Reconfig.sizes` row workers
    /// rebuild their geometry from.
    pub fn sizes(&self) -> Vec<usize> {
        self.plans
            .first()
            .map(|pl| pl.sizes.clone())
            .unwrap_or_default()
    }

    /// Whether this epoch's widths differ from the Algorithm-1 equal
    /// split — i.e. a heterogeneity-aware weighted plan is in effect
    /// and the broadcast must carry an explicit sizes row.
    pub fn is_weighted(&self) -> bool {
        let p = self.plans.len();
        if p <= 1 {
            return false;
        }
        let n: usize = self.plans[0].sizes.iter().sum();
        match partition_sizes(n, p) {
            Ok(eq) => self.plans[0].sizes != eq,
            Err(_) => true,
        }
    }
}

/// The live device set plus the machinery to re-plan over it.
pub struct ClusterView {
    base: Mode,
    n: usize,
    causal: bool,
    alive: Vec<bool>,
    epoch: u64,
    /// (P', L') -> plan set. Geometry depends only on the counts (which
    /// devices survive decides hosting, not spans), so every distinct
    /// geometry — Eq. 16 re-picks and serving-path L overrides alike —
    /// is planned once per process and re-entering it is free.
    cache: BTreeMap<(usize, usize), Arc<Vec<PartitionPlan>>>,
}

impl ClusterView {
    /// A full-strength cluster serving `base` over an N-token window.
    pub fn new(base: Mode, n: usize, causal: bool) -> Result<ClusterView> {
        let p = base.p();
        if p == 0 || n < p {
            bail!("invalid cluster geometry N={n} P={p}");
        }
        if let Mode::Prism { l, .. } = base {
            if l == 0 || l > n / p {
                bail!("invalid base geometry N={n} P={p} L={l}");
            }
        }
        let mut view = ClusterView {
            base,
            n,
            causal,
            alive: vec![true; p],
            epoch: 0,
            cache: BTreeMap::new(),
        };
        view.current()?; // validate + warm the full-strength plan
        Ok(view)
    }

    /// Rebuild a view from replicated HA state (`coordinator::ha`): the
    /// promoted standby resumes mastering at the shadowed epoch with
    /// the shadowed live set instead of restarting at full strength /
    /// epoch 0 — so its very next membership change broadcasts an epoch
    /// strictly above anything the dead master ever issued, and the
    /// workers' fail-closed epoch validation makes it win any race
    /// against stale frames.
    pub fn resume(base: Mode, n: usize, causal: bool, epoch: u64,
                  live: &[usize]) -> Result<ClusterView> {
        let mut view = ClusterView::new(base, n, causal)?;
        for d in 0..base.p() {
            if !live.contains(&d) {
                view.alive[d] = false;
            }
        }
        if view.live() == 0 {
            bail!("resumed view has no live devices");
        }
        view.epoch = epoch;
        view.current()?; // validate + warm the resumed geometry's plan
        Ok(view)
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The full-strength strategy this cluster was configured with.
    pub fn base(&self) -> Mode {
        self.base
    }

    /// Live device count.
    pub fn live(&self) -> usize {
        self.alive.iter().filter(|a| **a).count()
    }

    pub fn is_alive(&self, device: usize) -> bool {
        self.alive.get(device).copied().unwrap_or(false)
    }

    /// Live physical device ids in rank order.
    pub fn live_devices(&self) -> Vec<usize> {
        (0..self.alive.len()).filter(|&d| self.alive[d]).collect()
    }

    /// Written-off physical device ids — the re-join sweep's worklist
    /// (the mesh master re-dials each of these between batches).
    pub fn dead_devices(&self) -> Vec<usize> {
        (0..self.alive.len()).filter(|&d| !self.alive[d]).collect()
    }

    /// Every configured device is live again (the post-re-join
    /// acceptance: the soak's final geometry must be the full P).
    pub fn full_strength(&self) -> bool {
        self.alive.iter().all(|&a| a)
    }

    /// Mark a device dead and bump the epoch. Allowed down to zero live
    /// devices (the cluster is then unservable until a re-join —
    /// `current` reports it instead of panicking).
    pub fn fail_device(&mut self, device: usize) -> Result<()> {
        if device >= self.alive.len() {
            bail!("device {device} out of range (P={})", self.alive.len());
        }
        if !self.alive[device] {
            bail!("device {device} is already dead");
        }
        self.alive[device] = false;
        self.epoch += 1;
        Ok(())
    }

    /// The dual of `fail_device`: a repaired device re-joins and the
    /// next epoch plans over the grown P'.
    pub fn add_device(&mut self, device: usize) -> Result<()> {
        if device >= self.alive.len() {
            bail!("device {device} out of range (P={})", self.alive.len());
        }
        if self.alive[device] {
            bail!("device {device} is already live");
        }
        self.alive[device] = true;
        self.epoch += 1;
        Ok(())
    }

    /// The base strategy re-shaped to `p_now` devices: same family,
    /// Eq. 16 re-picks L for PRISM (preserved CR target), and P'=1
    /// collapses every family to `Single` — the one-shot
    /// `runner::degraded_mode` answer, here driven by the live count.
    pub fn mode_for(&self, p_now: usize) -> Result<Mode> {
        if p_now == 0 {
            bail!("no live devices");
        }
        Ok(degraded_mode(self.base, p_now, self.n))
    }

    /// (P', L') decode geometry for the current membership. Unlike
    /// `mode_for`, L' stays the Eq. 16 re-pick even at P'=1: a decode
    /// session still needs a segment plan for its single partition.
    pub fn geometry(&self) -> Result<(usize, usize)> {
        let p_now = self.live();
        if p_now == 0 {
            bail!("no live devices");
        }
        let l = match self.base {
            Mode::Prism { p, l, .. } => replan_l(self.n, p, l, p_now),
            _ => 0,
        };
        Ok((p_now, l))
    }

    /// Plan set for one mode's geometry, cached by (P, L).
    fn plans_for(&mut self, mode: Mode) -> Result<Arc<Vec<PartitionPlan>>> {
        let key = (mode.p(), mode.l());
        if let Some(cached) = self.cache.get(&key) {
            return Ok(cached.clone());
        }
        let pls = match mode {
            Mode::Single => vec![single_plan(self.n, self.causal)],
            Mode::Voltage { p } => plans(self.n, p, 0, self.causal)?,
            Mode::Prism { p, l, .. } => plans(self.n, p, l, self.causal)?,
        };
        let arc = Arc::new(pls);
        self.cache.insert(key, arc.clone());
        Ok(arc)
    }

    /// Current epoch's plan snapshot (plans cached per geometry).
    pub fn current(&mut self) -> Result<EpochPlan> {
        let devices = self.live_devices();
        let mode = self.mode_for(devices.len())?;
        Ok(EpochPlan {
            epoch: self.epoch,
            mode,
            plans: self.plans_for(mode)?,
            devices,
        })
    }

    /// Heterogeneity-aware re-plan: split N proportionally to the
    /// measured `speeds` (one per live rank, from
    /// `profile::FleetProfile::speeds`), L-floor clamped so every
    /// partition still hosts its segment plan, and bump the epoch so
    /// the weighted geometry propagates like any membership change.
    ///
    /// The weighted plan set deliberately *bypasses* the (P', L') cache
    /// — different speed vectors share the same key — and is never
    /// inserted into it, so a later `current()` (e.g. after a
    /// membership change, when stale measurements must not linger)
    /// falls back to the cached Algorithm-1 equal split.
    pub fn replan_with_speeds(&mut self, speeds: &[f64])
                              -> Result<EpochPlan> {
        let devices = self.live_devices();
        let p_now = devices.len();
        if p_now == 0 {
            bail!("no live devices");
        }
        if speeds.len() != p_now {
            bail!("{} speeds for {p_now} live devices", speeds.len());
        }
        let mode = self.mode_for(p_now)?;
        let plans = if p_now == 1 {
            // a single survivor has nothing to balance
            self.plans_for(mode)?
        } else {
            let mut sizes = weighted_partition_sizes(self.n, speeds)?;
            clamp_sizes_min(&mut sizes, mode.l().max(1))?;
            Arc::new(plans_with_sizes(self.n, sizes, mode.l(),
                                      self.causal)?)
        };
        self.epoch += 1;
        Ok(EpochPlan { epoch: self.epoch, mode, plans, devices })
    }

    /// The "no distributed grid left" answer: a Single-mode snapshot of
    /// the current epoch with an *empty* device list — the serving
    /// master runs the whole stack itself and every worker is
    /// released. Kept here (plan cached like any other geometry) so
    /// the view stays the one owner of the epoch -> plan mapping.
    pub fn single_fallback(&mut self) -> Result<EpochPlan> {
        Ok(EpochPlan {
            epoch: self.epoch,
            mode: Mode::Single,
            plans: self.plans_for(Mode::Single)?,
            devices: vec![],
        })
    }

    /// Current epoch over the live devices, serving an explicit mode
    /// instead of the Eq. 16 re-pick — the serving path's artifact-grid
    /// fallback (e.g. the base L clamped to P' when the re-picked L has
    /// no AOT artifact). The plan set is cached like any other
    /// geometry, so the view stays the one owner of the epoch -> plan
    /// mapping; `mode.p()` must match the live count.
    pub fn current_with_mode(&mut self, mode: Mode) -> Result<EpochPlan> {
        let devices = self.live_devices();
        if devices.is_empty() {
            bail!("no live devices");
        }
        if mode.p() != devices.len() {
            bail!("override mode P={} does not match {} live devices",
                  mode.p(), devices.len());
        }
        Ok(EpochPlan {
            epoch: self.epoch,
            mode,
            plans: self.plans_for(mode)?,
            devices,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repartitions_with_eq16_l_and_rejoins() {
        let base = Mode::Prism { p: 4, l: 4, duplicated: true };
        let mut view = ClusterView::new(base, 128, true).unwrap();
        assert_eq!(view.epoch(), 0);
        let full = view.current().unwrap();
        assert_eq!(full.mode, base);
        assert_eq!(full.devices, vec![0, 1, 2, 3]);
        assert_eq!(full.plans.len(), 4);

        // kill 1 of 4: P'=3 PRISM (not Single) with Eq. 16's L'=5
        view.fail_device(2).unwrap();
        let p3 = view.current().unwrap();
        assert_eq!(p3.epoch, 1);
        assert_eq!(p3.mode, Mode::Prism { p: 3, l: 5, duplicated: true });
        assert_eq!(p3.devices, vec![0, 1, 3]);
        assert_eq!(p3.rank_of(3), Some(2));
        assert_eq!(p3.rank_of(2), None);

        // a second loss: P'=2 with L'=8
        view.fail_device(0).unwrap();
        let p2 = view.current().unwrap();
        assert_eq!(p2.mode, Mode::Prism { p: 2, l: 8, duplicated: true });
        assert_eq!(p2.devices, vec![1, 3]);

        // re-join restores P'=3; the plan set is the *cached* one
        view.add_device(2).unwrap();
        let p3b = view.current().unwrap();
        assert_eq!(p3b.epoch, 3);
        assert_eq!(p3b.mode, p3.mode);
        assert_eq!(p3b.devices, vec![1, 2, 3]);
        assert!(Arc::ptr_eq(&p3b.plans, &p3.plans), "plan cache miss");

        // full strength again: the original geometry
        view.add_device(0).unwrap();
        let again = view.current().unwrap();
        assert_eq!(again.epoch, 4);
        assert_eq!(again.mode, base);
        assert!(Arc::ptr_eq(&again.plans, &full.plans));
    }

    #[test]
    fn single_collapse_and_zero_live() {
        let base = Mode::Prism { p: 2, l: 4, duplicated: true };
        let mut view = ClusterView::new(base, 32, true).unwrap();
        view.fail_device(0).unwrap();
        let one = view.current().unwrap();
        assert_eq!(one.mode, Mode::Single);
        assert_eq!(one.devices, vec![1]);
        assert_eq!(one.plans.len(), 1);
        // decode geometry keeps the Eq. 16 L even at P'=1
        assert_eq!(view.geometry().unwrap(), (1, 8));
        // losing the last device is recordable but unservable
        view.fail_device(1).unwrap();
        assert_eq!(view.live(), 0);
        assert!(view.current().is_err());
        assert!(view.geometry().is_err());
        // and a re-join makes it servable again
        view.add_device(1).unwrap();
        assert_eq!(view.current().unwrap().mode, Mode::Single);
    }

    #[test]
    fn membership_guards() {
        let base = Mode::Voltage { p: 3 };
        let mut view = ClusterView::new(base, 30, false).unwrap();
        assert!(view.fail_device(9).is_err());
        assert!(view.add_device(0).is_err()); // already live
        view.fail_device(1).unwrap();
        assert!(view.fail_device(1).is_err()); // already dead
        assert_eq!(view.current().unwrap().mode, Mode::Voltage { p: 2 });
        assert!(view.is_alive(0) && !view.is_alive(1));
        assert!(!view.is_alive(7));
        assert_eq!(view.live_devices(), vec![0, 2]);
        assert_eq!(view.dead_devices(), vec![1]);
        assert!(!view.full_strength());
        view.add_device(1).unwrap();
        assert!(view.full_strength());
        view.fail_device(1).unwrap();
        // voltage has no landmark geometry
        assert_eq!(view.geometry().unwrap(), (2, 0));
        // invalid base geometries are rejected up front
        assert!(ClusterView::new(
            Mode::Prism { p: 2, l: 0, duplicated: true }, 32, true)
            .is_err());
        assert!(ClusterView::new(
            Mode::Prism { p: 2, l: 17, duplicated: true }, 32, true)
            .is_err());
        assert!(ClusterView::new(Mode::Voltage { p: 40 }, 32, true)
            .is_err());
    }

    #[test]
    fn override_mode_is_cached_and_guarded() {
        let base = Mode::Prism { p: 4, l: 4, duplicated: true };
        let mut view = ClusterView::new(base, 64, true).unwrap();
        view.fail_device(1).unwrap();
        // the serving path's fallback: base L instead of Eq. 16's L'=5
        let fb = Mode::Prism { p: 3, l: 4, duplicated: true };
        let a = view.current_with_mode(fb).unwrap();
        assert_eq!(a.mode, fb);
        assert_eq!(a.devices, vec![0, 2, 3]);
        assert_eq!(a.plans.len(), 3);
        assert_eq!(a.plans[0].l, 4);
        // cached like any other geometry
        let b = view.current_with_mode(fb).unwrap();
        assert!(Arc::ptr_eq(&a.plans, &b.plans));
        // and distinct from the Eq. 16 plan set for the same P'
        let eq16 = view.current().unwrap();
        assert!(!Arc::ptr_eq(&a.plans, &eq16.plans));
        // the override must match the live strength
        assert!(view
            .current_with_mode(Mode::Prism { p: 2, l: 4,
                                             duplicated: true })
            .is_err());
    }

    #[test]
    fn weighted_replan_bumps_epoch_and_bypasses_the_cache() {
        let base = Mode::Prism { p: 4, l: 4, duplicated: true };
        let mut view = ClusterView::new(base, 32, true).unwrap();
        let eq = view.current().unwrap();
        assert!(!eq.is_weighted());
        assert_eq!(eq.sizes(), vec![8, 8, 8, 8]);

        // a 4x straggler at rank 3: fewer tokens, L-floor respected
        let w = view.replan_with_speeds(&[1.0, 1.0, 1.0, 0.25]).unwrap();
        assert_eq!(w.epoch, 1);
        assert_eq!(w.mode, base);
        assert!(w.is_weighted());
        let sizes = w.sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 32);
        assert!(sizes.iter().all(|&s| s >= 4), "L-floor broken {sizes:?}");
        assert!(sizes[3] < sizes[0], "straggler kept equal share");
        // never cached: the equal-split snapshot is untouched
        let eq2 = view.current().unwrap();
        assert_eq!(eq2.sizes(), vec![8, 8, 8, 8]);
        assert!(Arc::ptr_eq(&eq2.plans, &eq.plans));

        // equal speeds reproduce Algorithm 1 exactly (balanced N)
        let flat = view.replan_with_speeds(&[1.0; 4]).unwrap();
        assert_eq!(flat.sizes(), vec![8, 8, 8, 8]);
        assert!(!flat.is_weighted());
        assert_eq!(flat.epoch, 2);

        // wrong arity / hostile speeds fail closed, no epoch bump
        assert!(view.replan_with_speeds(&[1.0, 1.0]).is_err());
        assert!(view.replan_with_speeds(&[1.0, 1.0, 0.0, 1.0]).is_err());
        assert_eq!(view.epoch(), 2);

        // after a loss the weighted re-plan covers the shrunken P'
        view.fail_device(1).unwrap();
        let w3 = view.replan_with_speeds(&[1.0, 1.0, 0.5]).unwrap();
        assert_eq!(w3.devices, vec![0, 2, 3]);
        assert_eq!(w3.sizes().iter().sum::<usize>(), 32);
        assert_eq!(w3.plans.len(), 3);
        // a lone survivor has nothing to balance
        view.fail_device(0).unwrap();
        view.fail_device(3).unwrap();
        let lone = view.replan_with_speeds(&[1.0]).unwrap();
        assert_eq!(lone.mode, Mode::Single);
        assert!(!lone.is_weighted());
    }

    #[test]
    fn single_base_stays_single() {
        let mut view = ClusterView::new(Mode::Single, 16, true).unwrap();
        assert_eq!(view.current().unwrap().mode, Mode::Single);
        assert_eq!(view.live(), 1);
        assert!(view.fail_device(0).is_ok());
        assert!(view.current().is_err());
    }
}
