//! Context compressors: Segment Means (the paper) and ablation baselines.
//!
//! The paper compares PRISM only against Voltage (no compression). To
//! place Segment Means itself, this module implements alternative
//! fixed-rate compressors with the *same* wire footprint (L rows of D per
//! partition) that drop into the same AOT executables — only the context
//! tensor and the repetition semantics change:
//!
//!   * `SegmentMeans` — Algorithm 2 (the paper's choice);
//!   * `CenterToken`  — transmit each segment's middle row verbatim
//!                      (subsampling; counts still apply);
//!   * `FirstToken`   — each segment's first row (strided subsampling);
//!   * `GlobalMean`   — L copies of the partition mean (rate-matched
//!                      degenerate baseline; lower bound).
//!
//! Because the block executables compute Segment Means of their outputs
//! internally (the Layer-1 kernel), non-default compressors are applied
//! by the coordinator on the returned partition outputs instead — same
//! bytes on the wire, measured in the same way.

use anyhow::Result;

use super::plan::segment_counts;
use super::segmeans::segment_means;
use crate::runtime::Tensor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Compressor {
    SegmentMeans,
    CenterToken,
    FirstToken,
    GlobalMean,
}

impl Compressor {
    pub fn parse(s: &str) -> Result<Compressor> {
        Ok(match s {
            "segment-means" | "means" => Compressor::SegmentMeans,
            "center" | "center-token" => Compressor::CenterToken,
            "first" | "first-token" => Compressor::FirstToken,
            "global-mean" => Compressor::GlobalMean,
            other => anyhow::bail!("unknown compressor '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Compressor::SegmentMeans => "segment-means",
            Compressor::CenterToken => "center-token",
            Compressor::FirstToken => "first-token",
            Compressor::GlobalMean => "global-mean",
        }
    }

    /// Compress (B, N_p, D) -> (B, L, D).
    pub fn compress(&self, x: &Tensor, l: usize) -> Result<Tensor> {
        match self {
            Compressor::SegmentMeans => segment_means(x, l),
            Compressor::CenterToken => pick_rows(x, l, RowPick::Center),
            Compressor::FirstToken => pick_rows(x, l, RowPick::First),
            Compressor::GlobalMean => {
                let m = segment_means(x, 1)?; // (B, 1, D)
                let (b, _, d) = (x.shape[0], x.shape[1], x.shape[2]);
                let src = m.f32s()?;
                let mut out = Vec::with_capacity(b * l * d);
                for bi in 0..b {
                    for _ in 0..l {
                        out.extend_from_slice(&src[bi * d..(bi + 1) * d]);
                    }
                }
                Tensor::from_f32(vec![b, l, d], out)
            }
        }
    }
}

enum RowPick {
    Center,
    First,
}

fn pick_rows(x: &Tensor, l: usize, pick: RowPick) -> Result<Tensor> {
    let (b, n_p, d) = (x.shape[0], x.shape[1], x.shape[2]);
    let counts = segment_counts(n_p, l)?;
    let src = x.f32s()?;
    let mut out = Vec::with_capacity(b * l * d);
    for bi in 0..b {
        let base = bi * n_p * d;
        let mut row = 0usize;
        for &c in &counts {
            let r = match pick {
                RowPick::Center => row + c / 2,
                RowPick::First => row,
            };
            out.extend_from_slice(&src[base + r * d..base + (r + 1) * d]);
            row += c;
        }
    }
    Tensor::from_f32(vec![b, l, d], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(rows: &[f32]) -> Tensor {
        Tensor::from_f32(vec![1, rows.len(), 1], rows.to_vec()).unwrap()
    }

    #[test]
    fn center_and_first_pick_expected_rows() {
        // N_p=5, L=2 -> segments [0,1], [2,3,4]
        let x = t(&[10., 20., 30., 40., 50.]);
        let c = Compressor::CenterToken.compress(&x, 2).unwrap();
        assert_eq!(c.f32s().unwrap(), &[20., 40.]); // centers 1, 3
        let f = Compressor::FirstToken.compress(&x, 2).unwrap();
        assert_eq!(f.f32s().unwrap(), &[10., 30.]);
    }

    #[test]
    fn global_mean_repeats_partition_mean() {
        let x = t(&[1., 2., 3., 6.]);
        let g = Compressor::GlobalMean.compress(&x, 3).unwrap();
        assert_eq!(g.f32s().unwrap(), &[3., 3., 3.]);
    }

    #[test]
    fn segment_means_is_default_algorithm2() {
        let x = t(&[2., 4., 6., 8.]);
        let z = Compressor::SegmentMeans.compress(&x, 2).unwrap();
        assert_eq!(z.f32s().unwrap(), &[3., 7.]);
    }

    #[test]
    fn parse_names() {
        for n in ["segment-means", "center-token", "first-token",
                  "global-mean"] {
            assert_eq!(Compressor::parse(n).unwrap().name(), n);
        }
        assert!(Compressor::parse("zzz").is_err());
    }

    #[test]
    fn all_compressors_same_shape() {
        let x = Tensor::from_f32(vec![2, 7, 3], vec![0.5; 42]).unwrap();
        for c in [Compressor::SegmentMeans, Compressor::CenterToken,
                  Compressor::FirstToken, Compressor::GlobalMean] {
            let z = c.compress(&x, 3).unwrap();
            assert_eq!(z.shape, vec![2, 3, 3], "{}", c.name());
        }
    }
}
