//! Layer-3 coordinator: the paper's master/worker protocol (Fig. 1),
//! partition/exchange planning, the deterministic trace executor, and the
//! threaded serving runtime.
pub mod cluster;
pub mod compressor;
pub mod ha;
pub mod plan;
pub mod remote;
pub mod runner;
pub mod segmeans;

pub use cluster::{ClusterView, EpochPlan};
pub use compressor::Compressor;
pub use ha::{standby_of, GossipCfg, Liveness, Shadow};
pub use remote::RemoteCoordinator;
pub use plan::{clamp_sizes_min, plans, plans_with_sizes, single_plan,
               weighted_partition_sizes, PartitionPlan};
pub use runner::{bias_for, degraded_mode, Mode, RunTrace, Runner};
