//! Timing harness for the `benches/` binaries (criterion is not in the
//! offline vendor set): warmup + fixed-iteration timing with
//! median/p95, plus shared helpers for locating artifacts and reading
//! bench parameters from the environment.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::Manifest;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_secs: f64,
    pub median_secs: f64,
    pub p95_secs: f64,
    pub min_secs: f64,
}

impl BenchStats {
    pub fn per_op(&self) -> String {
        fn fmt(s: f64) -> String {
            if s >= 1.0 {
                format!("{s:.3} s")
            } else if s >= 1e-3 {
                format!("{:.3} ms", s * 1e3)
            } else {
                format!("{:.1} µs", s * 1e6)
            }
        }
        format!("median {} (mean {}, p95 {}, n={})",
                fmt(self.median_secs), fmt(self.mean_secs),
                fmt(self.p95_secs), self.iters)
    }
}

/// Run `f` `iters` times after `warmup` unmeasured runs.
pub fn bench(warmup: usize, iters: usize, mut f: impl FnMut())
             -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(f64::total_cmp);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchStats {
        iters,
        mean_secs: mean,
        median_secs: samples[samples.len() / 2],
        p95_secs: samples[(samples.len() * 95 / 100)
            .min(samples.len() - 1)],
        min_secs: samples[0],
    }
}

/// Artifacts root: $PRISM_ARTIFACTS or ./artifacts.
pub fn artifacts_root() -> PathBuf {
    std::env::var("PRISM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Load the manifest or explain how to produce it.
pub fn load_manifest() -> Result<Arc<Manifest>> {
    Ok(Arc::new(Manifest::load(&artifacts_root())?))
}

/// Sample cap for accuracy sweeps: $PRISM_EVAL_LIMIT (0 = full dataset).
pub fn eval_limit(default: usize) -> usize {
    std::env::var("PRISM_EVAL_LIMIT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// True when artifacts exist; benches print a pointer and exit otherwise.
pub fn require_artifacts() -> Option<Arc<Manifest>> {
    match load_manifest() {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping: {e:#}\n(run `make artifacts` first)");
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_and_orders() {
        let mut n = 0;
        let st = bench(2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(st.iters, 10);
        assert!(st.min_secs <= st.median_secs);
        assert!(st.median_secs <= st.p95_secs);
        assert!(!st.per_op().is_empty());
    }

    #[test]
    fn eval_limit_default() {
        std::env::remove_var("PRISM_EVAL_LIMIT");
        assert_eq!(eval_limit(77), 77);
    }
}
