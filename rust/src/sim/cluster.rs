//! The soak harness: the *real* serving loops at scale on the virtual
//! clock, under open-loop load and membership churn.
//!
//! Topology mirrors the threaded server: P worker threads — each
//! running the actual `server::worker_loop_with` protocol loop over a
//! [`SimNetMt`] endpoint, with a deterministic closed-form
//! [`BlockRunner`] standing in for the AOT engine — and the harness
//! thread playing the master: it batches eval arrivals through the
//! shared `server::BatcherCore`, drives decode streams through the
//! shared `server::DecodeCore`, scatters/gathers with the real
//! `run_distributed`, and recovers from churn with the real
//! `probe_dead`/`reconfigure`/re-admission code. Every distributed
//! batch result is asserted equal to a sequential lockstep reference of
//! the same stand-in blocks, so a protocol bug (mixed epochs, dropped
//! shares, wrong routing) fails loudly, not silently.
//!
//! Determinism: the conductor in `SimNetMt` serializes execution (one
//! runnable participant at a time, earliest-event-first), so the whole
//! soak — completion counts, epochs, virtual-time histograms — is a
//! pure function of the [`SoakCfg`] seed. Two runs must compare equal,
//! and the suite asserts they do.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::cluster::{ClusterView, EpochPlan};
use crate::coordinator::segmeans::segment_means;
use crate::coordinator::Mode;
use crate::coordinator::{standby_of, Shadow};
use crate::decode::{RefCfg, RefGpt};
use crate::metrics::tenancy::TenancyReport;
use crate::metrics::Histogram;
use crate::net::message::Msg;
use crate::net::simnet::{MtEndpoint, SimNetMt};
use crate::net::transport::Transport;
use crate::net::LinkModel;
use crate::profile::FleetProfile;
use crate::runtime::{ModelCfg, Tensor};
use crate::server::{adaptive_replan, broadcast_reconfig, elastic_plan,
                    probe_dead, reconfigure, run_distributed,
                    stack_rows, BatcherCore, BlockRunner, DecodeCore,
                    DecodeEvent, FaultPolicy, PassOutcome, Request,
                    SchedCtl, SchedPolicy, worker_loop_with};
use crate::tenant::{Admission, RequestClass, TenancyCfg, Verdict};
use crate::util::quant::WireFmt;
use crate::util::rng::Rng;

use super::churn::{ChurnEvent, ChurnSchedule};
use super::workload::{Arrival, WorkloadCfg, WorkloadGen};

/// Multi-tenant serving knobs for the soak: the admission gate's
/// [`TenancyCfg`] plus the decode scheduler policy driven by it.
#[derive(Debug, Clone, PartialEq)]
pub struct SimTenancy {
    /// Admission gate: per-tenant quotas and per-class shed caps.
    pub cfg: TenancyCfg,
    /// Class-aware decode scheduling (Interactive first). With this
    /// off the same load runs under the class-blind FIFO baseline —
    /// the run the prioritized one must beat on Interactive p99.
    pub classful: bool,
    /// Decode quanta spent per tick (0 = advance every running
    /// stream, the legacy sweep).
    pub tick_quanta: usize,
    /// Concurrently-running decode session bound; admissions beyond
    /// it queue per class (0 = unbounded, legacy).
    pub max_running: usize,
    /// The Interactive-class p99 completion-latency SLO (virtual
    /// seconds) the tenants suite asserts.
    pub interactive_slo: f64,
}

/// Master high-availability knobs for the soak (ISSUE 10): the worker
/// gossip/suspicion parameters handed to every worker's `FaultPolicy`,
/// plus the master's state-sync replication cadence.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimHa {
    /// Worker-to-worker liveness gossip cadence (virtual).
    pub gossip_every: Duration,
    /// Gossip windows of master silence before the quorum may declare
    /// it dead. The window (`gossip_every * suspect_after`) must
    /// comfortably outlast the gather/exchange deadlines: workers do
    /// not gossip mid-barrier, so a full reconfigure cycle is the
    /// longest master silence an idle, healthy standby ever observes —
    /// the deadband is what makes a slow master different from a dead
    /// one.
    pub suspect_after: u32,
    /// Pinned standby (`None` = lowest-ranked live worker).
    pub standby: Option<usize>,
    /// Master -> standby state-sync cadence (virtual seconds); the
    /// same beat stamps every live worker's liveness view of the
    /// master, independent of workload gaps.
    pub sync_every: f64,
}

impl Default for SimHa {
    fn default() -> SimHa {
        SimHa {
            gossip_every: Duration::from_millis(100),
            suspect_after: 12,
            standby: Some(0),
            sync_every: 0.05,
        }
    }
}

/// Soak configuration; [`SoakCfg::small`] is the suite preset.
#[derive(Clone)]
pub struct SoakCfg {
    pub seed: u64,
    /// Eval-mesh strength: P workers + the master (id P).
    pub p: usize,
    /// Landmarks per partition of the eval PRISM mode.
    pub l: usize,
    /// Eval batch size (the batcher's fill trigger).
    pub batch: usize,
    /// Synthetic eval model: window, width, block count.
    pub n: usize,
    pub d: usize,
    pub layers: usize,
    /// The virtual network every frame pays transfer time on.
    pub link: LinkModel,
    pub workload: WorkloadCfg,
    pub churn: ChurnSchedule,
    /// Failure-detection deadlines (master gather + worker exchange
    /// barrier), in virtual time.
    pub deadline: Duration,
    /// Batcher flush window (virtual).
    pub flush_after: Duration,
    /// Decode scheduler cadence (virtual seconds per tick; every tick
    /// advances each active stream by one quantum).
    pub decode_tick: f64,
    /// Modeled compute seconds charged per tensor element per block on
    /// the conductor's virtual clock. 0.0 (the `small` preset) keeps
    /// compute free — only wire time advances the clock, exactly the
    /// pre-heterogeneity behaviour — so homogeneous soaks stay
    /// bit-identical across versions.
    pub cost_per_elem: f64,
    /// Per-device speed multipliers (empty = all 1.0). A device at
    /// 0.25 pays 4x the modeled compute time per element — the
    /// straggler shape the adaptive re-partitioner must absorb.
    pub speeds: Vec<f64>,
    /// Enable heterogeneity-aware adaptive re-partitioning on the sim
    /// master with this deadband (None = static equal split; worker
    /// profiles still aggregate but never change the geometry).
    pub replan_deadband: Option<f64>,
    /// Worker profile-heartbeat pacing on the virtual clock.
    pub heartbeat_every: Duration,
    /// Bandwidth-aware planning: fold measured link bandwidth into the
    /// adaptive split and relay exchange traffic around edges degraded
    /// below this fraction of the fleet's best (None = pure-compute
    /// split, exactly the pre-link-planning behaviour).
    pub link_factor: Option<f64>,
    /// Feed the decode scheduler's modeled per-token compute into the
    /// fleet profile (and run the adaptive trigger at decode ticks), so
    /// a decode-only workload can reach `should_replan` too.
    pub decode_profile: bool,
    /// Multi-tenant serving: admission gate + class-aware decode
    /// scheduling (None = untenanted legacy soak, exactly the
    /// pre-tenancy behaviour).
    pub tenancy: Option<SimTenancy>,
    /// Shape of the decode-side reference model (its `vocab` is
    /// overridden from the workload at run time). The tenants preset
    /// shrinks it so 10k+ streams fit the suite's wall budget.
    pub decode_model: RefCfg,
    /// Master high availability: gossip liveness on the workers plus
    /// standby state-sync from the master (`None` = HA off, exactly
    /// the pre-HA soak).
    pub ha: Option<SimHa>,
}

/// Named-constructor builder for [`SoakCfg`]: every preset starts from
/// [`SoakCfg::builder`]'s defaults (the `small` suite shape) and
/// overrides only what it is about. The default churn schedule is
/// derived from the *final* workload at [`SoakBuilder::build`] time —
/// kill/revive cycles spread over ~80% of the expected workload span —
/// so presets that resize the workload keep a well-placed schedule
/// without restating it.
pub struct SoakBuilder {
    cfg: SoakCfg,
    churn: Option<ChurnSchedule>,
}

impl SoakBuilder {
    pub fn workload(mut self, workload: WorkloadCfg) -> Self {
        self.cfg.workload = workload;
        self
    }

    /// Explicit churn schedule (replaces the derived default).
    pub fn churn(mut self, churn: ChurnSchedule) -> Self {
        self.churn = Some(churn);
        self
    }

    pub fn cost_per_elem(mut self, cost: f64) -> Self {
        self.cfg.cost_per_elem = cost;
        self
    }

    pub fn speeds(mut self, speeds: Vec<f64>) -> Self {
        self.cfg.speeds = speeds;
        self
    }

    pub fn replan_deadband(mut self, deadband: Option<f64>) -> Self {
        self.cfg.replan_deadband = deadband;
        self
    }

    pub fn link_factor(mut self, factor: Option<f64>) -> Self {
        self.cfg.link_factor = factor;
        self
    }

    pub fn decode_profile(mut self, on: bool) -> Self {
        self.cfg.decode_profile = on;
        self
    }

    pub fn tenancy(mut self, tenancy: Option<SimTenancy>) -> Self {
        self.cfg.tenancy = tenancy;
        self
    }

    pub fn decode_model(mut self, model: RefCfg) -> Self {
        self.cfg.decode_model = model;
        self
    }

    /// Arm master high availability (gossip liveness + standby
    /// state-sync).
    pub fn ha(mut self, ha: Option<SimHa>) -> Self {
        self.cfg.ha = ha;
        self
    }

    pub fn build(self) -> SoakCfg {
        let SoakBuilder { mut cfg, churn } = self;
        cfg.churn = churn.unwrap_or_else(|| {
            // churn spread over ~80% of the expected workload span, so
            // the last revive lands while traffic still flows
            let horizon = cfg.workload.mean_interarrival
                * cfg.workload.requests as f64
                * 0.8;
            ChurnSchedule::cycles(cfg.seed ^ 0xC0FFEE, 4, horizon, 2)
        });
        cfg
    }
}

impl SoakCfg {
    /// Start a builder at the suite defaults: P=4 PRISM over a
    /// 1 Gbps / 50 µs mesh, tiny synthetic shapes (the soak stresses
    /// the protocol, not FLOPs), default workload, derived churn.
    pub fn builder(seed: u64) -> SoakBuilder {
        SoakBuilder {
            cfg: SoakCfg {
                seed,
                p: 4,
                l: 4,
                batch: 4,
                n: 32,
                d: 8,
                layers: 3,
                link: LinkModel::new(1000.0, 0.05),
                workload: WorkloadCfg::default(),
                churn: ChurnSchedule::none(),
                deadline: Duration::from_millis(500),
                flush_after: Duration::from_millis(4),
                decode_tick: 0.002,
                cost_per_elem: 0.0,
                speeds: Vec::new(),
                replan_deadband: None,
                heartbeat_every: Duration::from_millis(100),
                link_factor: None,
                decode_profile: false,
                tenancy: None,
                decode_model: RefCfg {
                    vocab: 0, // overridden from the workload at run time
                    n: 64,
                    d: 16,
                    heads: 2,
                    layers: 2,
                    ffn: 32,
                },
                ha: None,
            },
            churn: None,
        }
    }

    /// The suite preset: the builder defaults, unchanged.
    pub fn small(seed: u64) -> SoakCfg {
        SoakCfg::builder(seed).build()
    }

    /// The heterogeneous-fleet preset: modeled per-block compute time
    /// on the virtual clock, one 4x-slow straggler on device 3, and a
    /// mid-run thermal throttle that halves device 1 — membership
    /// churn off, so every epoch transition in the report is an
    /// *adaptive* one. With `replan_deadband` cleared this same config
    /// runs the fleet under the static equal split: the baseline the
    /// adaptive run must beat on p99.
    pub fn hetero(seed: u64) -> SoakCfg {
        let workload = WorkloadCfg::default();
        let horizon =
            workload.mean_interarrival * workload.requests as f64;
        SoakCfg::builder(seed)
            .churn(ChurnSchedule::new(vec![(
                horizon * 0.5,
                ChurnEvent::throttle(1, 0.5),
            )]))
            .cost_per_elem(1e-5)
            .speeds(vec![1.0, 1.0, 1.0, 0.25])
            .replan_deadband(Some(0.35))
            .build()
    }

    /// Virtual timestamp of the hetero preset's throttle event.
    pub fn hetero_throttle_at(&self) -> Option<f64> {
        self.churn.next_at()
    }

    /// The link-degradation preset: an equal-speed fleet over a healthy
    /// mesh, with one directed edge (0 -> 1) delay-ramped mid-run — a
    /// congested last-hop radio, not a slow device. The profiler
    /// observes the crawl through arrival-timed exchange frames, and
    /// the link-aware trigger must answer with exactly one bounded
    /// re-plan that shrinks the penalized endpoints' slices and relays
    /// the degraded edge through a healthy peer. With `link_factor`
    /// cleared the same config is the direct baseline the relayed plan
    /// must beat on eval p99.
    pub fn linkplan(seed: u64) -> SoakCfg {
        let workload = WorkloadCfg::default();
        let horizon =
            workload.mean_interarrival * workload.requests as f64;
        // two-step ramp on the same edge: the profiler's EWMA sees a
        // worsening crawl, not a single cliff — the deadband still has
        // to fold both into ONE re-plan (hysteresis, not ping-pong)
        SoakCfg::builder(seed)
            .churn(ChurnSchedule::new(vec![
                (horizon * 0.35, ChurnEvent::link_delay(0, 1, 0.05)),
                (horizon * 0.45, ChurnEvent::link_delay(0, 1, 0.15)),
            ]))
            .cost_per_elem(1e-5)
            .replan_deadband(Some(0.35))
            .link_factor(Some(0.5))
            .build()
    }

    /// Virtual timestamp of the linkplan preset's first delay step.
    pub fn linkplan_degrade_at(&self) -> Option<f64> {
        self.churn.next_at()
    }

    /// The multi-tenant preset (ISSUE 9): tens of thousands of mostly
    /// decode streams from 40 Zipf-skewed tenants in a 15/45/40
    /// interactive/batch/best-effort mix, pushed through the admission
    /// gate (ascending per-class shed caps, per-tenant quotas hot
    /// tenant 0 must hit) and a classful bounded decode scheduler —
    /// under the default kill/revive churn, on a decode model shrunk
    /// so 10k+ streams stay inside the suite's wall budget.
    pub fn tenants(seed: u64) -> SoakCfg {
        // Offered load vs service capacity, on the virtual clock: 500
        // arrivals/s (mean_interarrival 2 ms), 97% decode. A stream
        // needs ceil(prompt/2) prefill quanta + `steps` token quanta —
        // 5.17 on average for prompt 2-4 / steps 2-5 — and the
        // scheduler spends tick_quanta=4 per 2 ms tick, i.e. ~387
        // streams/s. Demand above BestEffort's cap (700), demand of
        // the two upper classes (~60% of offers, ~290/s) below it:
        // the backlog climbs to ~700 and parks there, shedding
        // best-effort, while batch (cap 1400) and interactive (2800)
        // stay clear. Tenant 0 draws ~27% of offers under Zipf(1.1),
        // ~110/s against a 60/s quota — the greedy client the
        // per-tenant buckets must throttle; every other tenant fits.
        let workload = WorkloadCfg {
            requests: 16_000,
            mean_interarrival: 0.002,
            tail_alpha: 1.5,
            decode_fraction: 0.97,
            vocab: 20,
            prompt_len: (2, 4),
            steps: (2, 5),
            tenants: 40,
            tenant_skew: 1.1,
            class_mix: (0.15, 0.45),
        };
        SoakCfg::builder(seed)
            .workload(workload)
            .decode_model(RefCfg {
                vocab: 0,
                n: 32,
                d: 8,
                heads: 1,
                layers: 1,
                ffn: 16,
            })
            .tenancy(Some(SimTenancy {
                cfg: TenancyCfg {
                    tenants: 40,
                    quota_rate: 60.0,
                    quota_burst: 120.0,
                    shed_caps: [700, 1400, 2800],
                },
                classful: true,
                tick_quanta: 4,
                max_running: 48,
                interactive_slo: 0.25,
            }))
            .build()
    }

    /// The class-blind baseline of [`SoakCfg::tenants`]: identical
    /// load, identical admission gate, identical scheduler bounds —
    /// but FIFO across classes. The prioritized run must meet the
    /// Interactive p99 SLO this one misses.
    pub fn tenants_unprioritized(seed: u64) -> SoakCfg {
        let mut cfg = SoakCfg::tenants(seed);
        if let Some(t) = cfg.tenancy.as_mut() {
            t.classful = false;
        }
        cfg
    }

    /// The master-HA preset (ISSUE 10): the default mixed workload
    /// with gossip liveness and standby state-sync armed, one worker
    /// kill/revive cycle as background churn, and the headline event —
    /// the master itself killed at half the horizon. The pinned
    /// standby (worker 0) must detect the death by gossip quorum,
    /// promote from its shadowed state, and hand the cluster back to
    /// the role address; the freed slot re-joins as a worker at 3/4
    /// horizon (the old master's machine coming back demoted).
    pub fn ha(seed: u64) -> SoakCfg {
        let workload = WorkloadCfg::default();
        let horizon =
            workload.mean_interarrival * workload.requests as f64;
        SoakCfg::builder(seed)
            .churn(ChurnSchedule::new(vec![
                (horizon * 0.2, ChurnEvent::Kill(2)),
                (horizon * 0.35, ChurnEvent::Revive(2)),
                (horizon * 0.5, ChurnEvent::KillMaster),
                (horizon * 0.75, ChurnEvent::Revive(0)),
            ]))
            .ha(Some(SimHa::default()))
            .build()
    }

    /// The no-kill twin of [`SoakCfg::ha`]: identical seed, workload,
    /// and worker churn, gossip and state-sync still armed — but the
    /// master survives. Its per-stream digests are the ground truth
    /// the HA run must reproduce bit-for-bit, and its
    /// `promotions == 0` is the no-false-positive deadband check: a
    /// slow-but-alive master must never be usurped.
    pub fn ha_no_kill(seed: u64) -> SoakCfg {
        let workload = WorkloadCfg::default();
        let horizon =
            workload.mean_interarrival * workload.requests as f64;
        SoakCfg::builder(seed)
            .churn(ChurnSchedule::new(vec![
                (horizon * 0.2, ChurnEvent::Kill(2)),
                (horizon * 0.35, ChurnEvent::Revive(2)),
            ]))
            .ha(Some(SimHa::default()))
            .build()
    }
}

/// What one soak run produced. `PartialEq` is the determinism check:
/// two runs of the same seed must compare equal, histograms included.
#[derive(Debug, Clone, PartialEq)]
pub struct SoakReport {
    pub seed: u64,
    pub eval_requests: usize,
    pub eval_responses: usize,
    pub eval_batches: u64,
    pub decode_streams: usize,
    pub decode_completed: usize,
    pub decode_aborted: usize,
    pub decode_tokens: usize,
    /// Final epoch of the serving view (number of membership/geometry
    /// transitions the run survived).
    pub final_epoch: u64,
    /// Live strength at the end (full P when every churned worker
    /// re-joined).
    pub final_p: usize,
    /// `ClusterView::full_strength` at the end — the post-re-join
    /// acceptance bit: every configured device is serving again.
    pub full_strength: bool,
    pub virtual_secs: f64,
    pub wire_bytes: usize,
    pub eval_latency: Histogram,
    pub decode_latency: Histogram,
    /// Adaptive re-partition trail: `(virtual_secs, new_epoch)` for
    /// every profile-triggered weighted re-plan the master applied
    /// (empty when `replan_deadband` is None or the fleet never left
    /// the deadband).
    pub replans: Vec<(f64, u64)>,
    /// Relay-route trail: `(virtual_secs, relay table)` for every
    /// adaptive re-plan that shipped a non-empty relay table (empty
    /// unless `link_factor` is on and a degraded edge got routed).
    pub relay_plans: Vec<(f64, Vec<(u32, u32, u32)>)>,
    /// Final directed-edge byte matrix (`[from][to]`, master = row P):
    /// the direct-vs-relay evidence — a relayed edge's direct bytes
    /// stop growing while its via legs carry the traffic.
    pub edge_bytes: Vec<Vec<usize>>,
    /// Multi-tenant telemetry: per-class admission/shed counters and
    /// completion-latency histograms, per-tenant counters, and the
    /// admission gate's load watermarks. Default (all-zero) when the
    /// run had no tenancy configured.
    pub tenancy: TenancyReport,
    /// `ChurnEvent::KillMaster` events executed.
    pub master_kills: usize,
    /// Standby promotions the harness resumed mastering from.
    pub promotions: usize,
    /// Virtual seconds from each master kill to the promoted
    /// standby's state handover landing at the role address.
    pub promotion_latency: Vec<f64>,
    /// Decode streams re-admitted from the replicated snapshot.
    pub readmitted_streams: usize,
    /// Decode streams the snapshot missed (admitted after the last
    /// sync beat) and the clients re-sent after the takeover.
    pub resubmitted_streams: usize,
    /// Per-stream FNV-1a digest of the deduplicated token sequence
    /// every client observed — the HA run must match its no-kill
    /// twin's map exactly (bit-identical replay across the failover).
    pub stream_digests: BTreeMap<u64, u64>,
}

impl SoakReport {
    /// Requests that went unanswered — the zero-drops acceptance is
    /// `dropped() == 0`. Shed requests never entered the system, so
    /// they are not counted here: with tenancy on, "no drops" means
    /// *every admitted request* completed.
    pub fn dropped(&self) -> usize {
        (self.eval_requests - self.eval_responses)
            + (self.decode_streams - self.decode_completed)
    }

    /// Admitted requests (what entered the serving system).
    pub fn requests(&self) -> usize {
        self.eval_requests + self.decode_streams
    }

    /// Everything the workload offered, admitted or shed.
    pub fn offered(&self) -> usize {
        self.requests() + self.tenancy.shed() as usize
    }
}

/// The sim's artifact grid: every geometry exists (the stand-in blocks
/// are closed-form), in both the failure and the re-join direction —
/// one definition so the two re-plan paths cannot diverge.
fn sim_avail(_: Mode) -> bool {
    true
}

/// The deterministic closed-form block stand-in:
/// `x' = 0.9 x + 0.1 mean(ctx) + 0.01 (layer+1)` row-wise, with the
/// PRISM share computed by the *real* `segment_means` — so exchange
/// shapes and wire bytes match what an engine-backed worker would put
/// on the mesh, and the whole pass is reproducible sequential f32.
fn sim_block(x: &Tensor, ctx: &Tensor, layer: usize) -> Result<Tensor> {
    let (b, np, d) = (x.shape[0], x.shape[1], x.shape[2]);
    let xs = x.f32s()?;
    let cs = ctx.f32s()?;
    let rows = ctx.shape[1]; // peers * L (0 on a single-device pass)
    let mut out = vec![0.0f32; xs.len()];
    let lc = 0.01 * (layer as f32 + 1.0);
    for bi in 0..b {
        let mut cmean = vec![0.0f32; d];
        if rows > 0 {
            for r in 0..rows {
                let s = &cs[(bi * rows + r) * d
                    ..(bi * rows + r + 1) * d];
                for (m, v) in cmean.iter_mut().zip(s) {
                    *m += v;
                }
            }
            let inv = 1.0 / rows as f32;
            for m in cmean.iter_mut() {
                *m *= inv;
            }
        }
        for i in 0..np {
            let base = (bi * np + i) * d;
            for j in 0..d {
                out[base + j] = 0.9 * xs[base + j] + 0.1 * cmean[j] + lc;
            }
        }
    }
    Tensor::from_f32(vec![b, np, d], out)
}

/// The sim-side [`BlockRunner`]: `ensure` just records the geometry,
/// `run` applies [`sim_block`] and derives the PRISM share with the
/// real `segment_means`. When compute-time modeling is on, each `run`
/// also prices the block — `cost_per_elem * elems / speed(wid)` — and
/// hands it to the protocol loop through `modeled_cost`, which charges
/// it on the virtual clock and feeds the online device profiler.
struct SimBlocks {
    modes: BTreeMap<String, Mode>,
    wid: usize,
    /// Modeled seconds per tensor element per block (0.0 = off).
    cost_per_elem: f64,
    /// Per-device speed multipliers as `f64` bits, shared with the
    /// harness thread so a [`ChurnEvent::Throttle`] changes the rate
    /// mid-run without restarting the worker.
    speeds: Arc<Vec<AtomicU64>>,
    /// Price of the most recent `run`, consumed by `modeled_cost`.
    last_cost: Option<Duration>,
}

impl SimBlocks {
    fn new(wid: usize, cost_per_elem: f64,
           speeds: Arc<Vec<AtomicU64>>) -> SimBlocks {
        SimBlocks {
            modes: BTreeMap::new(),
            wid,
            cost_per_elem,
            speeds,
            last_cost: None,
        }
    }
}

impl BlockRunner for SimBlocks {
    fn ensure(&mut self, mode: Mode, rank: usize) -> Result<String> {
        let key = format!("sim-{}-p{}-l{}-r{rank}", mode.name(),
                          mode.p(), mode.l());
        self.modes.insert(key.clone(), mode);
        Ok(key)
    }

    fn run(&mut self, exec: &str, layer: usize, args: &[&Tensor])
           -> Result<Vec<Tensor>> {
        let mode = *self
            .modes
            .get(exec)
            .with_context(|| format!("unknown sim executable {exec}"))?;
        if self.cost_per_elem > 0.0 {
            let elems: usize = args[0].shape.iter().product();
            let speed = f64::from_bits(
                self.speeds[self.wid].load(Ordering::Relaxed));
            let secs =
                self.cost_per_elem * elems as f64 / speed.max(1e-9);
            self.last_cost = Some(Duration::from_secs_f64(secs));
        }
        let out = sim_block(args[0], args[1], layer)?;
        match mode {
            Mode::Prism { l, .. } => {
                let share = segment_means(&out, l)?;
                Ok(vec![out, share])
            }
            _ => Ok(vec![out]),
        }
    }

    fn modeled_cost(&mut self) -> Option<Duration> {
        self.last_cost.take()
    }
}

/// Sequential lockstep reference of the distributed pass on `plan`:
/// partitions advance layer by layer, exchanging segment means exactly
/// as the worker protocol does — the gathered distributed output must
/// equal this bit-for-bit.
fn reference_pass(plan: &EpochPlan, x0: &Tensor, layers: usize)
                  -> Result<Tensor> {
    let pls = &plan.plans;
    let l = plan.mode.l();
    let b = x0.shape[0];
    let d = *x0.shape.last().context("x0 wants a (B, N, D) shape")?;
    let mut xs: Vec<Tensor> = pls
        .iter()
        .map(|pl| x0.slice1(pl.start(), pl.start() + pl.n_p()))
        .collect::<Result<_>>()?;
    // layer-0 context comes from the *input* partitions (what the
    // master ships inside the Job); later layers use the previous
    // block's shares
    let share_of = |xp: &Tensor| -> Result<Tensor> {
        if l > 0 {
            segment_means(xp, l)
        } else {
            Ok(xp.clone())
        }
    };
    let mut shares: Vec<Tensor> =
        xs.iter().map(&share_of).collect::<Result<_>>()?;
    for layer in 0..layers {
        let mut next = Vec::with_capacity(pls.len());
        for (rank, pl) in pls.iter().enumerate() {
            let peers = pl.peers();
            let ctx = if peers.is_empty() {
                Tensor::from_f32(vec![b, 0, d], Vec::new())?
            } else {
                let refs: Vec<&Tensor> =
                    peers.iter().map(|&j| &shares[j]).collect();
                Tensor::concat1(&refs)?
            };
            next.push(sim_block(&xs[rank], &ctx, layer)?);
        }
        xs = next;
        shares = xs.iter().map(&share_of).collect::<Result<_>>()?;
    }
    let refs: Vec<&Tensor> = xs.iter().collect();
    Tensor::concat1(&refs)
}

/// One eval request riding the batcher.
struct EvalReq {
    row: Tensor,
    arrived: f64,
}

/// The client's view of one decode stream: what it sent (enough to
/// re-send the request verbatim after a master failover) and every
/// token it has accepted so far. The token list is the dedup ledger —
/// a promoted master replays the tail of a re-admitted stream, and a
/// fully re-sent stream replays from its first token, so the client
/// drops duplicate `(id, index)` events after asserting they match
/// the original bit-for-bit.
struct StreamLedger {
    prompt: Vec<i32>,
    steps: usize,
    tenant: u32,
    class: RequestClass,
    replica_wire: WireFmt,
    tokens: Vec<i32>,
    done: bool,
}

/// FNV-1a over a token sequence: the per-stream digest the HA suite
/// compares against the no-kill twin's.
fn fnv1a64(tokens: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1_0000_0000_01b3);
        }
    }
    h
}

fn spawn_sim_worker(net: &SimNetMt, wid: usize, model: &ModelCfg,
                    mode: Mode, faults: &FaultPolicy, join_epoch: u32,
                    blocks: SimBlocks)
                    -> Result<JoinHandle<Result<()>>> {
    // register on the harness thread, BEFORE the OS schedules the new
    // thread: the conductor must know about the participant from the
    // instant this function returns, or wake order would race
    let ep = net.endpoint(wid);
    let model = model.clone();
    let faults = faults.clone();
    let h = std::thread::Builder::new()
        .name(format!("sim-worker-{wid}"))
        .spawn(move || {
            worker_loop_with(model, mode, blocks, ep, faults,
                             join_epoch)
        })?;
    Ok(h)
}

/// Run one batch through the real elastic master pass and assert the
/// result against the lockstep reference.
#[allow(clippy::too_many_arguments)]
fn run_eval_batch(cfg: &SoakCfg, net: &SimNetMt, ep: &mut MtEndpoint,
                  view: &mut ClusterView, current: &mut EpochPlan,
                  faults: &FaultPolicy, batch: Vec<EvalReq>,
                  job_id: &mut u64,
                  mut fleet: Option<&mut FleetProfile>,
                  replans: &mut Vec<(f64, u64)>,
                  relay_plans: &mut Vec<(f64, Vec<(u32, u32, u32)>)>,
                  eval_latency: &mut Histogram,
                  eval_responses: &mut usize) -> Result<()> {
    let rows: Vec<&Tensor> = batch.iter().map(|r| &r.row).collect();
    let x0 = stack_rows(&rows, cfg.batch)?;
    loop {
        if current.p() <= 1 {
            // the master serves alone (same fallback as the real
            // masters; the reference IS the single-device compute, so
            // there is nothing independent to compare against)
            reference_pass(current, &x0, cfg.layers)?;
            break;
        }
        match run_distributed(current, ep, &x0, *job_id,
                              faults.gather_deadline,
                              fleet.as_deref_mut())? {
            PassOutcome::Done(x) => {
                // the lockstep reference is computed independently of
                // the mesh: a protocol bug (mixed epochs, dropped or
                // misrouted shares) fails loudly here
                let expect = reference_pass(current, &x0, cfg.layers)?;
                if x != expect {
                    bail!("distributed batch {job_id} diverged from \
                           the lockstep reference on epoch {}",
                          current.epoch);
                }
                break;
            }
            PassOutcome::Dead(missing) => {
                let probed = probe_dead(ep, &missing, cfg.p);
                let dead = if probed.is_empty() {
                    missing
                } else {
                    probed
                };
                *current = reconfigure(&sim_avail, cfg.n, view, &dead,
                                       ep, cfg.p)?;
                if let Some(fp) = fleet.as_deref_mut() {
                    fp.membership_changed();
                }
            }
        }
    }
    *job_id += 1;
    // heterogeneity-aware adaptation, at the same safe point as the
    // threaded/mesh masters: between batches, from profile heartbeats
    // gathered during the pass
    if current.p() > 1 {
        if let Some(fp) = fleet.as_deref_mut() {
            if let Some((next, relays)) =
                adaptive_replan(ep, view, fp, &current.devices,
                                faults.link_factor)?
            {
                *current = next;
                replans.push((net.now_secs(), view.epoch()));
                if !relays.is_empty() {
                    relay_plans.push((net.now_secs(), relays));
                }
            }
        }
    }
    let done = net.now_secs();
    for r in &batch {
        eval_latency.record((done - r.arrived).max(0.0));
        *eval_responses += 1;
    }
    Ok(())
}

/// Drain decode events after a scheduler tick, recording completion
/// latencies on the virtual clock — both in the aggregate histogram
/// and in the completed stream's class bucket of the tenancy report.
/// The ledger dedups master-failover replays: a token event whose
/// index the client already holds must match bit-for-bit and is not
/// re-counted.
#[allow(clippy::too_many_arguments)]
fn drain_decode_events(rx: &Receiver<DecodeEvent>, now: f64,
                       meta: &mut BTreeMap<u64, (f64, RequestClass)>,
                       ledger: &mut BTreeMap<u64, StreamLedger>,
                       decode_latency: &mut Histogram,
                       tenancy: &mut TenancyReport,
                       tokens: &mut usize, completed: &mut usize,
                       aborted: &mut usize) {
    while let Ok(ev) = rx.try_recv() {
        if ev.token >= 0 {
            if let Some(st) = ledger.get_mut(&ev.id) {
                if ev.index < st.tokens.len() {
                    // a replayed token is the full-recompute
                    // continuation of the same log: divergence here
                    // means the replicated state was wrong
                    assert_eq!(st.tokens[ev.index], ev.token,
                               "stream {} replayed a diverging token \
                                at index {}", ev.id, ev.index);
                    continue; // duplicate: counted the first time
                }
                st.tokens.push(ev.token);
            }
            *tokens += 1;
        }
        if ev.done {
            if let Some(st) = ledger.get_mut(&ev.id) {
                st.done = true;
            }
            let (arrived, class) = meta
                .remove(&ev.id)
                .unwrap_or((now, RequestClass::Batch));
            let latency = (now - arrived).max(0.0);
            decode_latency.record(latency);
            if ev.token >= 0 {
                *completed += 1;
                tenancy.record_done(class, latency);
            } else {
                *aborted += 1;
            }
        }
    }
}

/// The soak: spawn the mesh, replay the seeded workload and churn
/// schedule on the virtual clock, and account everything.
pub fn run_soak(cfg: &SoakCfg) -> Result<SoakReport> {
    if cfg.p < 2 {
        bail!("the soak wants a distributed mesh (P >= 2)");
    }
    let mode = Mode::Prism { p: cfg.p, l: cfg.l, duplicated: true };
    let model = ModelCfg {
        name: "sim".into(),
        kind: "sim".into(),
        n: cfg.n,
        d: cfg.d,
        heads: 1,
        layers: cfg.layers,
        ffn: 0,
        vocab: 0,
        img: 0,
        patch: 0,
        causal: true,
    };
    let faults = FaultPolicy {
        gather_deadline: cfg.deadline,
        exchange_deadline: cfg.deadline,
        heartbeat_every: cfg.heartbeat_every,
        replan_deadband: cfg.replan_deadband,
        link_factor: cfg.link_factor,
        gossip_every: cfg.ha.as_ref().map(|h| h.gossip_every),
        suspect_after: cfg.ha.as_ref().map_or(3, |h| h.suspect_after),
        standby: cfg.ha.as_ref().and_then(|h| h.standby),
        ..FaultPolicy::default()
    };
    // per-device speed multipliers as f64 bits: shared with every
    // worker's SimBlocks so a Throttle event re-rates a device mid-run
    let speeds: Arc<Vec<AtomicU64>> = Arc::new(
        (0..cfg.p)
            .map(|w| {
                let s = cfg.speeds.get(w).copied().unwrap_or(1.0);
                AtomicU64::new(s.to_bits())
            })
            .collect());
    let net = SimNetMt::new(cfg.p + 1, cfg.link);
    let mut ep = net.endpoint(cfg.p);
    let mut workers: Vec<Option<JoinHandle<Result<()>>>> = (0..cfg.p)
        .map(|wid| {
            let blocks = SimBlocks::new(wid, cfg.cost_per_elem,
                                        speeds.clone());
            spawn_sim_worker(&net, wid, &model, mode, &faults, 0,
                             blocks)
                .map(Some)
        })
        .collect::<Result<_>>()?;

    let mut view = ClusterView::new(mode, cfg.n, true)?;
    let mut current = view.current()?;
    let mut fleet = cfg
        .replan_deadband
        .map(|db| FleetProfile::new(cfg.p, db));

    // decode side: the shared scheduling core on the reference model,
    // ticked at the configured virtual cadence
    let dec_cfg =
        RefCfg { vocab: cfg.workload.vocab, ..cfg.decode_model };
    let dec_model = Arc::new(RefGpt::tiny(cfg.seed ^ 0xD0, dec_cfg)?);
    let mut decode = DecodeCore::new(dec_model.clone(), cfg.p, 4,
                                     WireFmt::F32, 2)?;
    if cfg.decode_profile {
        decode.enable_profiling(cfg.cost_per_elem.max(1e-9),
                                speeds.clone());
    }
    // multi-tenant front door: the admission gate on the virtual
    // clock, plus the class-aware bounded decode scheduling policy
    let mut admission = cfg
        .tenancy
        .as_ref()
        .map(|t| Admission::new(t.cfg.clone()))
        .transpose()?;
    if let Some(t) = &cfg.tenancy {
        decode.set_policy(SchedPolicy {
            classful: t.classful,
            tick_quanta: t.tick_quanta,
            max_running: t.max_running,
        });
    }
    let (dec_tx, dec_rx) = channel::<DecodeEvent>();
    let mut dec_meta: BTreeMap<u64, (f64, RequestClass)> =
        BTreeMap::new();
    // the clients' side of the decode wire: request payloads for
    // re-sending across a master failover, plus the accepted-token
    // dedup ledger the per-stream digests are computed from
    let mut ledger: BTreeMap<u64, StreamLedger> = BTreeMap::new();

    let mut batcher: BatcherCore<EvalReq> =
        BatcherCore::new(cfg.batch, cfg.flush_after);
    let mut churn = cfg.churn.clone();
    let mut gen = WorkloadGen::new(cfg.seed, cfg.workload.clone());
    let mut next_arrival = gen.next();
    let mut rows_rng = Rng::new(cfg.seed ^ 0xE7A1);

    let mut report = SoakReport {
        seed: cfg.seed,
        eval_requests: 0,
        eval_responses: 0,
        eval_batches: 0,
        decode_streams: 0,
        decode_completed: 0,
        decode_aborted: 0,
        decode_tokens: 0,
        final_epoch: 0,
        final_p: 0,
        full_strength: false,
        virtual_secs: 0.0,
        wire_bytes: 0,
        eval_latency: Histogram::new(),
        decode_latency: Histogram::new(),
        replans: Vec::new(),
        relay_plans: Vec::new(),
        edge_bytes: Vec::new(),
        tenancy: TenancyReport::new(
            cfg.tenancy.as_ref().map_or(0, |t| t.cfg.tenants)),
        master_kills: 0,
        promotions: 0,
        promotion_latency: Vec::new(),
        readmitted_streams: 0,
        resubmitted_streams: 0,
        stream_digests: BTreeMap::new(),
    };
    let mut next_decode_tick: Option<f64> = None;
    let mut job_id = 0u64;
    // HA state-sync pacing: seq stamps make stale frames inert at the
    // standby, and the beat timer only rides while something else
    // still drives the run (it must not keep the loop alive forever)
    let mut sync_seq = 0u64;
    let mut next_sync: Option<f64> =
        cfg.ha.as_ref().map(|h| h.sync_every);

    loop {
        // the next event, in deterministic tie order:
        // churn < batch flush < decode tick < arrival < sync beat
        let mut cands: Vec<(f64, u8)> = Vec::new();
        if let Some(t) = churn.next_at() {
            cands.push((t, 0));
        }
        if let Some(dl) = batcher.deadline() {
            cands.push((dl.as_secs_f64(), 1));
        }
        if let Some(t) = next_decode_tick {
            cands.push((t, 2));
        }
        if let Some(item) = &next_arrival {
            cands.push((item.at, 3));
        }
        if !cands.is_empty() {
            if let Some(ts) = next_sync {
                cands.push((ts, 4));
            }
        }
        let Some(&(t, kind)) = cands
            .iter()
            .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
        else {
            break; // workload, batcher, decode, and churn all drained
        };
        ep.sleep_until(t);
        match kind {
            0 => {
                for ev in churn.pop_due(t) {
                    match ev {
                        ChurnEvent::Kill(w) => {
                            if !net.is_alive(w) {
                                continue;
                            }
                            net.kill(w);
                            if let Some(h) = workers[w].take() {
                                h.join().map_err(|_| {
                                    anyhow!("sim worker {w} panicked")
                                })??;
                            }
                            // membership verb to the decode scheduler
                            // (detection timing is the chaos suite's
                            // business; the soak pins recovery)
                            decode.ctl(SchedCtl::Fail(w));
                        }
                        ChurnEvent::Revive(w) => {
                            if net.is_alive(w) {
                                continue;
                            }
                            net.revive(w);
                            let join_epoch =
                                (view.epoch() + 1) as u32;
                            let blocks = SimBlocks::new(
                                w, cfg.cost_per_elem, speeds.clone());
                            workers[w] = Some(spawn_sim_worker(
                                &net, w, &model, mode, &faults,
                                join_epoch, blocks)?);
                            // master-side re-admission, symmetric to
                            // the threaded/mesh re-join paths. If no
                            // batch ran during the outage the master
                            // never wrote the device off; record the
                            // restart explicitly so the fresh thread
                            // gets an epoch to adopt.
                            if view.is_alive(w) {
                                view.fail_device(w)?;
                            }
                            view.add_device(w)?;
                            current = elastic_plan(&sim_avail, cfg.n,
                                                   &mut view)?;
                            broadcast_reconfig(&mut ep, &current, &[]);
                            decode.ctl(SchedCtl::Add(w));
                            if let Some(fp) = fleet.as_mut() {
                                fp.membership_changed();
                            }
                        }
                        ChurnEvent::Throttle(w, bits) => {
                            // DVFS/thermal re-rate: takes effect on
                            // the device's next block; the profiler
                            // notices through the heartbeats and the
                            // master re-plans once the drift leaves
                            // the deadband
                            speeds[w].store(bits, Ordering::Relaxed);
                        }
                        ChurnEvent::LinkDelay(f, t2, bits) => {
                            // a congested mesh edge, not a slow
                            // device: future frames on f -> t2 pay
                            // the extra delivery delay, the receiver
                            // times the crawl into its heartbeats,
                            // and the link-aware trigger routes
                            // around it
                            net.set_edge_delay(f, t2,
                                               f64::from_bits(bits));
                        }
                        ChurnEvent::KillMaster => {
                            let Some(ha) = cfg.ha.as_ref() else {
                                continue; // HA off: nobody can promote
                            };
                            report.master_kills += 1;
                            let killed_at = net.now_secs();
                            // The coordinator dies: every byte of its
                            // state is discarded, in-flight mail to it
                            // is lost. The role address itself stays
                            // routable (a supervisor VIP), so the
                            // promoted standby's handover frame can
                            // land here — on an empty inbox.
                            net.kill(cfg.p);
                            net.revive(cfg.p);
                            // client side of the eval wire: requests
                            // the dead batcher never flushed are
                            // unacknowledged, and their callers
                            // re-send them after the outage
                            let orphans =
                                batcher.drain().unwrap_or_default();
                            batcher = BatcherCore::new(cfg.batch,
                                                       cfg.flush_after);
                            // go silent and wait for the gossip quorum
                            // to detect the death and the standby to
                            // promote; its handover is the shadowed
                            // snapshot re-stamped at the bumped epoch
                            let mut shadow = Shadow::default();
                            loop {
                                let env = match ep.recv_deadline(
                                    Duration::from_secs(60))
                                {
                                    Ok(env) => env,
                                    Err(e) => bail!(
                                        "no promotion handover \
                                         reached the master role \
                                         address: {e}"),
                                };
                                if shadow.absorb(&env.msg) {
                                    break;
                                }
                                // anything else addressed to the dead
                                // master is stale and inert
                            }
                            let live: Vec<usize> = shadow
                                .live
                                .iter()
                                .map(|&d| d as usize)
                                .collect();
                            let sb = standby_of(&live, ha.standby)
                                .context("promoted handover names no \
                                          live standby")?;
                            // reproduce the promoted master's exact
                            // post-takeover plan: resume the shadowed
                            // view one epoch back, write the promoted
                            // standby out of the compute set (the
                            // bump that made its Reconfig beat any
                            // stale frame), and re-plan
                            view = ClusterView::resume(
                                mode, cfg.n, true,
                                (shadow.epoch as u64)
                                    .saturating_sub(1),
                                &live)?;
                            view.fail_device(sb)?;
                            current = elastic_plan(&sim_avail, cfg.n,
                                                   &mut view)?;
                            // the promoted worker's thread exited into
                            // mastering; mark its slot dark until the
                            // old master's machine re-joins demoted
                            // (a later Revive on the freed slot)
                            if let Some(h) = workers[sb].take() {
                                h.join().map_err(|_| {
                                    anyhow!("promoted standby {sb} \
                                             panicked")
                                })??;
                            }
                            net.kill(sb);
                            // rebuild the serving state from the
                            // replicated snapshot: fresh profiler and
                            // admission gate (watermarks reset; the
                            // token buckets restore, so a throttled
                            // tenant stays throttled), fresh decode
                            // core re-admitting the replicated
                            // directory on the post-promotion
                            // membership
                            fleet = cfg.replan_deadband.map(|db| {
                                FleetProfile::new(cfg.p, db)
                            });
                            admission = cfg
                                .tenancy
                                .as_ref()
                                .map(|tn| Admission::new(tn.cfg.clone()))
                                .transpose()?;
                            if let Some(adm) = admission.as_mut() {
                                let pairs: Vec<(f64, f64)> = shadow
                                    .buckets
                                    .iter()
                                    .map(|&(tk, ls)| {
                                        (f64::from_bits(tk),
                                         f64::from_bits(ls))
                                    })
                                    .collect();
                                adm.restore_buckets(&pairs);
                            }
                            decode = DecodeCore::new(
                                dec_model.clone(), cfg.p, 4,
                                WireFmt::F32, 2)?;
                            if cfg.decode_profile {
                                decode.enable_profiling(
                                    cfg.cost_per_elem.max(1e-9),
                                    speeds.clone());
                            }
                            if let Some(tn) = &cfg.tenancy {
                                decode.set_policy(SchedPolicy {
                                    classful: tn.classful,
                                    tick_quanta: tn.tick_quanta,
                                    max_running: tn.max_running,
                                });
                            }
                            for w in 0..cfg.p {
                                if !net.is_alive(w) {
                                    decode.ctl(SchedCtl::Fail(w));
                                }
                            }
                            // events the clients already hold land
                            // first, then the replicated directory
                            // re-admits, then the clients re-send the
                            // streams the snapshot missed (admitted
                            // after the last sync beat): zero drops is
                            // replication + client re-send + dedup,
                            // not lossless state transfer
                            drain_decode_events(
                                &dec_rx, net.now_secs(), &mut dec_meta,
                                &mut ledger,
                                &mut report.decode_latency,
                                &mut report.tenancy,
                                &mut report.decode_tokens,
                                &mut report.decode_completed,
                                &mut report.decode_aborted);
                            report.readmitted_streams += decode
                                .ha_restore(shadow.next_seq,
                                            &shadow.streams, &dec_tx);
                            let restored: BTreeSet<u64> = shadow
                                .streams
                                .iter()
                                .map(|s| s.id)
                                .collect();
                            let resend: Vec<u64> = ledger
                                .iter()
                                .filter(|(id, st)| {
                                    !st.done && !restored.contains(id)
                                })
                                .map(|(&id, _)| id)
                                .collect();
                            for id in resend {
                                let st = &ledger[&id];
                                let req =
                                    Request::decode(st.prompt.clone())
                                        .id(id)
                                        .tenant(st.tenant)
                                        .class(st.class)
                                        .steps(st.steps)
                                        .replicate(st.replica_wire)
                                        .build();
                                decode.admit(req.into_decode_job(
                                    dec_tx.clone())?);
                                report.resubmitted_streams += 1;
                            }
                            if decode.active() > 0
                                && next_decode_tick.is_none()
                            {
                                next_decode_tick = Some(
                                    net.now_secs() + cfg.decode_tick);
                            }
                            // re-sent eval requests open a fresh batch
                            // window now (their arrival stamps keep
                            // the outage inside their latency)
                            let resumed = Duration::from_secs_f64(
                                net.now_secs());
                            for r in orphans {
                                if let Some(batch) =
                                    batcher.push(r, resumed)
                                {
                                    report.eval_batches += 1;
                                    run_eval_batch(
                                        cfg, &net, &mut ep, &mut view,
                                        &mut current, &faults, batch,
                                        &mut job_id, fleet.as_mut(),
                                        &mut report.replans,
                                        &mut report.relay_plans,
                                        &mut report.eval_latency,
                                        &mut report.eval_responses)?;
                                }
                            }
                            report.promotions += 1;
                            report.promotion_latency
                                .push(net.now_secs() - killed_at);
                        }
                    }
                }
            }
            1 => {
                // poll with the exact Duration deadline: an f64
                // round-trip could land a hair short and never fire
                let due = batcher.deadline();
                if let Some(batch) =
                    due.and_then(|dl| batcher.poll(dl))
                {
                    report.eval_batches += 1;
                    run_eval_batch(cfg, &net, &mut ep, &mut view,
                                   &mut current, &faults, batch,
                                   &mut job_id, fleet.as_mut(),
                                   &mut report.replans,
                                   &mut report.relay_plans,
                                   &mut report.eval_latency,
                                   &mut report.eval_responses)?;
                }
            }
            2 => {
                decode.tick();
                // decode-path profiling (when armed): modeled per-token
                // compute reaches the same fleet profile the eval
                // heartbeats feed, and the adaptive trigger runs at the
                // tick boundary — a decode-only workload can drift past
                // the deadband and re-plan without a single eval batch
                if cfg.decode_profile {
                    if let Some(fp) = fleet.as_mut() {
                        for (dev, s) in decode.profile_samples() {
                            fp.observe(dev, &s);
                        }
                        if current.p() > 1 {
                            if let Some((next, relays)) =
                                adaptive_replan(&mut ep, &mut view, fp,
                                                &current.devices,
                                                faults.link_factor)?
                            {
                                current = next;
                                report.replans.push(
                                    (net.now_secs(), view.epoch()));
                                if !relays.is_empty() {
                                    report.relay_plans.push(
                                        (net.now_secs(), relays));
                                }
                            }
                        }
                    }
                }
                drain_decode_events(&dec_rx, net.now_secs(),
                                    &mut dec_meta, &mut ledger,
                                    &mut report.decode_latency,
                                    &mut report.tenancy,
                                    &mut report.decode_tokens,
                                    &mut report.decode_completed,
                                    &mut report.decode_aborted);
                next_decode_tick = if decode.active() > 0 {
                    Some(t + cfg.decode_tick)
                } else {
                    None
                };
            }
            3 => {
                let item = next_arrival.take().unwrap();
                next_arrival = gen.next();
                // the multi-tenant front door: per-class overload caps
                // against the current in-system load, then the
                // tenant's token bucket — a shed request never reaches
                // the batcher or the decode scheduler
                if let Some(adm) = admission.as_mut() {
                    let load = (report.eval_requests
                        - report.eval_responses)
                        + (report.decode_streams
                            - report.decode_completed
                            - report.decode_aborted);
                    match adm.offer(item.tenant, item.class, item.at,
                                    load)
                    {
                        Verdict::Admit => report
                            .tenancy
                            .record_admit(item.tenant, item.class),
                        Verdict::Shed(reason) => {
                            report.tenancy.record_shed(item.tenant,
                                                       item.class,
                                                       reason);
                            continue;
                        }
                    }
                }
                match item.kind {
                    Arrival::Eval => {
                        report.eval_requests += 1;
                        let row = Tensor::from_f32(
                            vec![1, cfg.n, cfg.d],
                            rows_rng.normal_vec(cfg.n * cfg.d, 0.5))?;
                        let req =
                            EvalReq { row, arrived: item.at };
                        if let Some(batch) = batcher
                            .push(req, Duration::from_secs_f64(item.at))
                        {
                            report.eval_batches += 1;
                            run_eval_batch(cfg, &net, &mut ep,
                                           &mut view, &mut current,
                                           &faults, batch, &mut job_id,
                                           fleet.as_mut(),
                                           &mut report.replans,
                                           &mut report.relay_plans,
                                           &mut report.eval_latency,
                                           &mut report.eval_responses)?;
                        }
                    }
                    Arrival::Decode { prompt, steps, replica_wire } => {
                        let id = report.decode_streams as u64;
                        report.decode_streams += 1;
                        dec_meta.insert(id, (item.at, item.class));
                        // the client's own copy of the request: if the
                        // master dies before this stream lands in a
                        // replicated snapshot, the client re-sends it
                        // verbatim after promotion
                        ledger.insert(id, StreamLedger {
                            prompt: prompt.clone(),
                            steps,
                            tenant: item.tenant,
                            class: item.class,
                            replica_wire,
                            tokens: Vec::new(),
                            done: false,
                        });
                        let req = Request::decode(prompt)
                            .id(id)
                            .tenant(item.tenant)
                            .class(item.class)
                            .steps(steps)
                            .replicate(replica_wire)
                            .build();
                        decode.admit(
                            req.into_decode_job(dec_tx.clone())?);
                        if next_decode_tick.is_none() {
                            next_decode_tick =
                                Some(item.at + cfg.decode_tick);
                        }
                    }
                }
            }
            _ => {
                // HA replication beat: ship the full master state to
                // the designated standby — epoch-tagged membership +
                // plan shape, admission token buckets, and the decode
                // session directory (replicated streams carry their
                // token logs) — and a light Heartbeat to every worker
                // so gossip keeps seeing a live master through arrival
                // gaps
                let Some(ha) = cfg.ha.as_ref() else { continue };
                sync_seq += 1;
                let (tag, mp, ml) = current.mode.to_wire();
                if let Some(sb) = standby_of(&current.devices,
                                             ha.standby) {
                    let (next_seq, streams) = decode.ha_snapshot();
                    let buckets: Vec<(u64, u64)> = admission
                        .as_ref()
                        .map(|adm| adm.export_buckets())
                        .unwrap_or_default()
                        .iter()
                        .map(|&(tk, ls)| (tk.to_bits(), ls.to_bits()))
                        .collect();
                    let _ = ep.send(sb, Msg::StateSync {
                        epoch: current.epoch as u32,
                        seq: sync_seq,
                        mode: tag,
                        p: mp,
                        l: ml,
                        live: current.devices.iter()
                                     .map(|&d| d as u32)
                                     .collect(),
                        next_seq,
                        buckets,
                        streams,
                    });
                }
                for &wid in &current.devices {
                    let _ = ep.send(wid, Msg::Heartbeat {
                        from: cfg.p as u32, seq: 0, profile: None });
                }
                next_sync = Some(t + ha.sync_every);
            }
        }
    }
    // stragglers: ctl-driven abort events can land between ticks
    drain_decode_events(&dec_rx, net.now_secs(), &mut dec_meta,
                        &mut ledger,
                        &mut report.decode_latency,
                        &mut report.tenancy,
                        &mut report.decode_tokens,
                        &mut report.decode_completed,
                        &mut report.decode_aborted);
    // per-stream token digests over the deduped client-side logs:
    // churn-invariant (decode is deterministic in prompt + model), so
    // a kill run and its no-kill twin must agree bit-for-bit
    for (&id, st) in &ledger {
        report.stream_digests.insert(id, fnv1a64(&st.tokens));
    }
    if let Some(adm) = &admission {
        report.tenancy.admit_load_max = adm.max_admit_load();
        report.tenancy.shed_load_min = adm.min_shed_load();
    }

    report.final_epoch = view.epoch();
    report.final_p = view.live();
    report.full_strength = view.full_strength();

    // release the mesh: Shutdown every live worker, then hand the
    // virtual clock over (dropping our endpoint deregisters the
    // master) so the deliveries can drain, and join
    for wid in 0..cfg.p {
        if net.is_alive(wid) {
            let _ = ep.send(wid, Msg::Shutdown);
        }
    }
    drop(ep);
    for (wid, h) in workers.iter_mut().enumerate() {
        if let Some(h) = h.take() {
            h.join()
                .map_err(|_| anyhow!("sim worker {wid} panicked"))??;
        }
    }
    report.virtual_secs = net.now_secs();
    report.wire_bytes = net.stats().total_bytes();
    report.edge_bytes = net.stats().edge_matrix();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A churn-free mini-soak completes everything, and the
    /// distributed results match the lockstep reference (asserted
    /// inside `run_eval_batch` on every batch).
    #[test]
    fn mini_soak_without_churn_completes_everything() {
        let mut cfg = SoakCfg::small(5);
        cfg.workload.requests = 60;
        cfg.churn = ChurnSchedule::none();
        let r = run_soak(&cfg).unwrap();
        assert_eq!(r.requests(), 60);
        assert_eq!(r.dropped(), 0, "{r:?}");
        assert_eq!(r.decode_aborted, 0);
        assert_eq!(r.final_epoch, 0, "no churn, no transitions");
        assert_eq!(r.final_p, cfg.p);
        assert!(r.full_strength);
        assert!(r.virtual_secs > 0.0 && r.wire_bytes > 0);
        assert!(r.eval_latency.count() as usize == r.eval_responses);
    }

    /// The hetero preset carries the straggler fleet, the adaptive
    /// deadband, and exactly one mid-run throttle event.
    #[test]
    fn hetero_preset_is_wellformed() {
        let cfg = SoakCfg::hetero(7);
        assert_eq!(cfg.speeds, vec![1.0, 1.0, 1.0, 0.25]);
        assert!(cfg.cost_per_elem > 0.0);
        assert!(cfg.replan_deadband.is_some());
        assert_eq!(cfg.churn.remaining(), 1);
        let at = cfg.hetero_throttle_at().unwrap();
        assert!(at > 0.0);
        let mut churn = cfg.churn.clone();
        assert_eq!(churn.pop_due(at),
                   vec![ChurnEvent::throttle(1, 0.5)]);
    }

    /// The linkplan preset degrades one directed mesh edge in two
    /// steps, with equal device speeds — so any re-plan it triggers is
    /// a *link* decision, not a straggler one.
    #[test]
    fn linkplan_preset_is_wellformed() {
        let cfg = SoakCfg::linkplan(3);
        assert!(cfg.speeds.is_empty(), "equal-speed fleet");
        assert!(cfg.cost_per_elem > 0.0);
        assert!(cfg.replan_deadband.is_some());
        assert!(cfg.link_factor.is_some());
        assert_eq!(cfg.churn.remaining(), 2);
        let t0 = cfg.linkplan_degrade_at().unwrap();
        assert!(t0 > 0.0);
        let mut churn = cfg.churn.clone();
        let evs = churn.pop_due(f64::INFINITY);
        assert_eq!(evs, vec![ChurnEvent::link_delay(0, 1, 0.05),
                             ChurnEvent::link_delay(0, 1, 0.15)]);
    }

    /// Modeled compute time pushes batches later on the virtual clock
    /// (the PR-5 refinement: the conductor charges per-layer compute,
    /// not just wire time), and with the adaptive trigger off the run
    /// never re-plans.
    #[test]
    fn modeled_compute_time_advances_the_virtual_clock() {
        let mut a = SoakCfg::small(5);
        a.workload.requests = 40;
        a.churn = ChurnSchedule::none();
        let base = run_soak(&a).unwrap();
        assert!(base.replans.is_empty());
        let mut b = a.clone();
        b.cost_per_elem = 1e-4;
        b.speeds = vec![1.0, 1.0, 1.0, 0.25];
        let slow = run_soak(&b).unwrap();
        assert_eq!(slow.dropped(), 0, "{slow:?}");
        assert!(slow.virtual_secs > base.virtual_secs,
                "modeled compute must advance the clock: {} vs {}",
                slow.virtual_secs, base.virtual_secs);
        assert!(slow.replans.is_empty(), "adaptive trigger was off");
        assert_eq!(slow.final_epoch, 0);
    }

    /// The builder's derived default churn matches what the flat
    /// `small` preset always carried, and explicit churn replaces it.
    #[test]
    fn builder_derives_default_churn_from_the_final_workload() {
        let small = SoakCfg::small(9);
        let w = WorkloadCfg::default();
        let horizon = w.mean_interarrival * w.requests as f64 * 0.8;
        let expect = ChurnSchedule::cycles(9 ^ 0xC0FFEE, 4, horizon, 2);
        assert_eq!(small.churn.remaining(), expect.remaining());
        assert_eq!(small.churn.next_at(), expect.next_at());
        assert!(small.tenancy.is_none());
        // a resized workload moves the derived schedule with it
        let big = SoakCfg::builder(9)
            .workload(WorkloadCfg { requests: 4000,
                                    ..WorkloadCfg::default() })
            .build();
        assert!(big.churn.next_at().unwrap()
                > small.churn.next_at().unwrap());
        // explicit churn wins over the derived default
        let none = SoakCfg::builder(9)
            .churn(ChurnSchedule::none())
            .build();
        assert_eq!(none.churn.remaining(), 0);
    }

    /// The tenants preset carries the admission gate, the classful
    /// bounded scheduler, and a 10k+-stream Zipf workload; the
    /// unprioritized twin differs ONLY in `classful`.
    #[test]
    fn tenants_preset_is_wellformed() {
        let cfg = SoakCfg::tenants(11);
        let t = cfg.tenancy.as_ref().unwrap();
        assert!(t.classful && t.max_running > 0 && t.tick_quanta > 0);
        assert!(t.interactive_slo > 0.0);
        t.cfg.validate().unwrap();
        assert_eq!(t.cfg.tenants, cfg.workload.tenants);
        assert!(cfg.workload.requests >= 10_000);
        assert!(cfg.workload.decode_fraction > 0.9);
        let (fi, fb) = cfg.workload.class_mix;
        assert!(fi > 0.0 && fb > 0.0 && fi + fb < 1.0,
                "all three classes must occur");
        assert!(cfg.churn.remaining() > 0, "churn interplay stays on");
        let base = SoakCfg::tenants_unprioritized(11);
        let bt = base.tenancy.as_ref().unwrap();
        assert!(!bt.classful);
        assert_eq!(bt.cfg, t.cfg);
        assert_eq!((bt.tick_quanta, bt.max_running, bt.interactive_slo),
                   (t.tick_quanta, t.max_running, t.interactive_slo));
    }

    /// A downsized tenancy soak balances its books: everything offered
    /// is either admitted or shed, every admitted request completes,
    /// and per-class completions land in the class histograms.
    #[test]
    fn mini_soak_with_tenancy_accounts_everything() {
        let mut cfg = SoakCfg::tenants(13);
        cfg.workload.requests = 400;
        cfg.churn = ChurnSchedule::none();
        let r = run_soak(&cfg).unwrap();
        assert_eq!(r.offered(), 400, "{:?}", r.tenancy);
        assert_eq!(r.tenancy.admitted() as usize, r.requests());
        assert_eq!(r.dropped(), 0, "{r:?}");
        assert_eq!(r.decode_aborted, 0);
        assert!(r.tenancy.enabled());
        let done: u64 = r.tenancy.classes.iter()
            .map(|c| c.completed)
            .sum();
        assert_eq!(done as usize, r.decode_completed);
        // untenanted runs keep an all-zero (Default) tenancy section
        let mut legacy = SoakCfg::small(13);
        legacy.workload.requests = 40;
        legacy.churn = ChurnSchedule::none();
        let lr = run_soak(&legacy).unwrap();
        assert!(!lr.tenancy.enabled());
        assert_eq!(lr.tenancy.shed(), 0);
        assert_eq!(lr.offered(), lr.requests());
    }

    /// The reference pass equals the single-partition closed form on a
    /// degenerate plan, and sim_block is deterministic.
    #[test]
    fn reference_pass_and_sim_block_are_deterministic() {
        let mut rng = Rng::new(9);
        let x = Tensor::from_f32(vec![2, 8, 4],
                                 rng.normal_vec(2 * 8 * 4, 1.0))
            .unwrap();
        let ctx = Tensor::from_f32(vec![2, 3, 4],
                                   rng.normal_vec(2 * 3 * 4, 1.0))
            .unwrap();
        let a = sim_block(&x, &ctx, 1).unwrap();
        let b = sim_block(&x, &ctx, 1).unwrap();
        assert_eq!(a, b);
        let mut view = ClusterView::new(
            Mode::Prism { p: 2, l: 2, duplicated: true }, 8, true)
            .unwrap();
        let plan = view.current().unwrap();
        let r1 = reference_pass(&plan, &x, 3).unwrap();
        let r2 = reference_pass(&plan, &x, 3).unwrap();
        assert_eq!(r1, r2);
        assert_eq!(r1.shape, x.shape);
    }

    /// The HA preset's suspicion window must outlast the longest
    /// legitimate master silence (a full reconfigure cycle), its sync
    /// beat must land several times per window, the master kill must
    /// sit mid-run, and its no-kill twin must differ ONLY in the
    /// master's fate.
    #[test]
    fn ha_preset_is_wellformed() {
        let cfg = SoakCfg::ha(19);
        let ha = cfg.ha.expect("HA armed");
        let window = ha.gossip_every.as_secs_f64()
            * ha.suspect_after as f64;
        assert!(window > cfg.deadline.as_secs_f64(),
                "suspicion window {window} must outlast the gather \
                 deadline {:?}: workers do not gossip mid-barrier",
                cfg.deadline);
        assert!(ha.sync_every > 0.0 && ha.sync_every < window / 2.0);
        assert_eq!(ha.standby, Some(0));
        let mut churn = cfg.churn.clone();
        let evs = churn.pop_due(f64::INFINITY);
        assert!(evs.contains(&ChurnEvent::KillMaster));
        assert_eq!(*evs.last().unwrap(), ChurnEvent::Revive(0),
                   "the freed slot re-joins demoted");
        let twin = SoakCfg::ha_no_kill(19);
        assert_eq!(twin.ha, cfg.ha);
        assert_eq!(twin.workload.requests, cfg.workload.requests);
        assert_eq!(twin.workload.mean_interarrival,
                   cfg.workload.mean_interarrival);
        let mut tc = twin.churn.clone();
        assert!(!tc.pop_due(f64::INFINITY)
                    .contains(&ChurnEvent::KillMaster));
    }

    /// A downsized master-kill soak: the standby detects the death by
    /// gossip quorum, promotes from its shadowed state, hands the
    /// cluster back to the role address, and no admitted request is
    /// dropped across the failover.
    #[test]
    fn mini_soak_survives_a_master_kill() {
        let mut cfg = SoakCfg::ha(17);
        // keep the wall budget small: the preset's churn stays at its
        // full-horizon positions, so this kill lands on an idle (but
        // gossiping) cluster — detection, promotion, and handover all
        // run; in-flight carryover is the full-size acceptance
        // suite's job (tests/ha.rs)
        cfg.workload.requests = 80;
        let r = run_soak(&cfg).unwrap();
        assert_eq!(r.master_kills, 1);
        assert_eq!(r.promotions, 1, "{r:?}");
        assert_eq!(r.dropped(), 0, "{r:?}");
        assert_eq!(r.promotion_latency.len(), 1);
        let lat = r.promotion_latency[0];
        assert!(lat > 0.0 && lat < 5.0,
                "promotion should take a few suspicion windows, \
                 got {lat}");
        assert!(r.full_strength, "slot 0 re-joined demoted");
        assert!(!r.stream_digests.is_empty());
        assert_eq!(r.stream_digests.len(), r.decode_streams);
    }
}
