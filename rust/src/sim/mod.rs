//! Deterministic full-stack soak simulation (FoundationDB-style).
//!
//! The chaos/elastic suites pin the fault machinery at the session and
//! transport layers; this module soaks the *serving* layer itself:
//! [`cluster::run_soak`] drives the real generic-over-`Transport`
//! serving loops — each worker thread literally executes
//! `server::worker_loop_with`, the master side runs the real
//! `run_distributed` / `probe` / `reconfigure` / re-admission code —
//! end-to-end on the conductor-scheduled virtual clock
//! (`net::SimNetMt`), with
//!
//! * [`workload::WorkloadGen`] — a seeded open-loop arrival process
//!   (heavy-tailed Pareto interarrivals) mixing eval batches for the
//!   shared `server::BatcherCore` with multi-stream decode sessions of
//!   varied prompt/length/replica wire for the shared
//!   `server::DecodeCore`;
//! * [`churn::ChurnSchedule`] — kill/revive events at virtual
//!   timestamps: a kill ends the worker's thread outright (the master
//!   discovers it through the real gather-deadline → probe → re-plan
//!   path), a revive respawns the thread on the dead slot and
//!   re-admits it with a `Msg::Reconfig`, restoring the full geometry;
//! * virtual-time latency/throughput histograms
//!   (`metrics::Histogram`) asserted against SLOs per seed.
//!
//! Everything is a pure function of the seed: thousands of requests
//! and aggressive churn replay bit-identically — histograms included —
//! in seconds of wall time with zero wall sleeps.

pub mod churn;
pub mod cluster;
pub mod workload;

pub use churn::{ChurnEvent, ChurnSchedule};
pub use cluster::{run_soak, SimHa, SimTenancy, SoakBuilder, SoakCfg,
                  SoakReport};
pub use workload::{Arrival, WorkloadCfg, WorkloadGen, WorkloadItem};
