//! Seeded open-loop workload generator for the soak simulation.
//!
//! Open-loop means arrival times are drawn independently of service:
//! a slow server falls behind and queues, which is exactly the regime
//! the "heavy traffic from millions of users" north star cares about.
//! Interarrivals are Pareto-distributed (heavy-tailed bursts — long
//! quiet stretches punctuated by packed arrivals), and each arrival is
//! either one eval row for the batcher or a decode stream with seeded
//! prompt length, generation length, and replica wire format.

use crate::util::quant::WireFmt;
use crate::util::rng::Rng;

/// What arrived.
#[derive(Debug, Clone, PartialEq)]
pub enum Arrival {
    /// One single-row eval request (batched by `server::BatcherCore`).
    Eval,
    /// One autoregressive decode stream for the scheduler.
    Decode {
        prompt: Vec<i32>,
        steps: usize,
        /// Replica wire of the stream's buddy replication (the
        /// replication cost knob): f32 exact, f16 half-cost lossy.
        replica_wire: WireFmt,
    },
}

/// One arrival at a virtual timestamp (seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadItem {
    pub at: f64,
    pub kind: Arrival,
}

/// Workload shape knobs.
#[derive(Debug, Clone)]
pub struct WorkloadCfg {
    /// Total arrivals to generate.
    pub requests: usize,
    /// Mean interarrival gap (virtual seconds).
    pub mean_interarrival: f64,
    /// Pareto tail exponent (> 1): smaller = heavier bursts.
    pub tail_alpha: f64,
    /// Fraction of arrivals that are decode streams.
    pub decode_fraction: f64,
    /// Decode vocabulary (prompt tokens drawn from `1..vocab`).
    pub vocab: usize,
    /// Inclusive prompt-length range.
    pub prompt_len: (usize, usize),
    /// Inclusive generated-token range (min >= 1: a zero-step stream
    /// closes with an abort event by contract).
    pub steps: (usize, usize),
}

impl Default for WorkloadCfg {
    fn default() -> WorkloadCfg {
        WorkloadCfg {
            requests: 1000,
            mean_interarrival: 0.02,
            tail_alpha: 1.5,
            decode_fraction: 0.3,
            vocab: 20,
            prompt_len: (3, 8),
            steps: (4, 12),
        }
    }
}

/// The seeded generator; an iterator over [`WorkloadItem`]s with
/// strictly increasing timestamps.
pub struct WorkloadGen {
    rng: Rng,
    cfg: WorkloadCfg,
    now: f64,
    emitted: usize,
}

impl WorkloadGen {
    pub fn new(seed: u64, cfg: WorkloadCfg) -> WorkloadGen {
        WorkloadGen { rng: Rng::new(seed), cfg, now: 0.0, emitted: 0 }
    }

    /// Pareto interarrival with the configured mean, capped at 50x so
    /// one tail draw cannot stall the whole soak: scale x_m is chosen
    /// so E[X] = alpha * x_m / (alpha - 1) equals `mean_interarrival`.
    fn interarrival(&mut self) -> f64 {
        let a = self.cfg.tail_alpha;
        let xm = self.cfg.mean_interarrival * (a - 1.0) / a;
        let u = self.rng.f64().max(1e-12);
        (xm / u.powf(1.0 / a)).min(self.cfg.mean_interarrival * 50.0)
    }
}

impl Iterator for WorkloadGen {
    type Item = WorkloadItem;

    fn next(&mut self) -> Option<WorkloadItem> {
        if self.emitted >= self.cfg.requests {
            return None;
        }
        self.emitted += 1;
        self.now += self.interarrival();
        let kind = if self.rng.chance(self.cfg.decode_fraction) {
            let (lo, hi) = self.cfg.prompt_len;
            let len = self.rng.range(lo, hi + 1);
            let prompt = (0..len)
                .map(|_| self.rng.range(1, self.cfg.vocab) as i32)
                .collect();
            let (slo, shi) = self.cfg.steps;
            let steps = self.rng.range(slo.max(1), shi + 1);
            // CR variety: a third of the streams take the half-cost
            // lossy f16 replica, the rest the exact f32 one
            let replica_wire = if self.rng.chance(0.33) {
                WireFmt::F16
            } else {
                WireFmt::F32
            };
            Arrival::Decode { prompt, steps, replica_wire }
        } else {
            Arrival::Eval
        };
        Some(WorkloadItem { at: self.now, kind })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_and_deterministic() {
        let cfg = WorkloadCfg { requests: 200, ..Default::default() };
        let a: Vec<WorkloadItem> =
            WorkloadGen::new(7, cfg.clone()).collect();
        let b: Vec<WorkloadItem> =
            WorkloadGen::new(7, cfg.clone()).collect();
        assert_eq!(a, b);
        let c: Vec<WorkloadItem> = WorkloadGen::new(8, cfg).collect();
        assert_ne!(a, c, "different seeds must differ");
        assert_eq!(a.len(), 200);
    }

    #[test]
    fn timestamps_increase_and_tail_is_heavy() {
        let cfg = WorkloadCfg { requests: 5000, ..Default::default() };
        let items: Vec<WorkloadItem> =
            WorkloadGen::new(11, cfg.clone()).collect();
        let mut last = 0.0;
        let mut max_gap: f64 = 0.0;
        for it in &items {
            assert!(it.at > last, "timestamps must strictly increase");
            max_gap = max_gap.max(it.at - last);
            last = it.at;
        }
        // heavy tail: some gap far beyond the mean, but capped
        assert!(max_gap > 4.0 * cfg.mean_interarrival,
                "max gap {max_gap} is not heavy-tailed");
        assert!(max_gap <= 50.0 * cfg.mean_interarrival + 1e-12);
    }

    #[test]
    fn mixes_eval_and_decode_with_valid_shapes() {
        let cfg = WorkloadCfg { requests: 2000, ..Default::default() };
        let items: Vec<WorkloadItem> =
            WorkloadGen::new(3, cfg.clone()).collect();
        let mut decodes = 0;
        let mut f16 = 0;
        for it in &items {
            if let Arrival::Decode { prompt, steps, replica_wire } =
                &it.kind
            {
                decodes += 1;
                assert!((cfg.prompt_len.0..=cfg.prompt_len.1)
                    .contains(&prompt.len()));
                assert!((cfg.steps.0..=cfg.steps.1).contains(steps));
                assert!(prompt.iter().all(|&t| {
                    t >= 1 && (t as usize) < cfg.vocab
                }));
                if *replica_wire == WireFmt::F16 {
                    f16 += 1;
                }
            }
        }
        // fractions in the right ballpark (seeded, not flaky)
        assert!(decodes > 450 && decodes < 750, "decodes {decodes}");
        assert!(f16 > 0 && f16 < decodes, "f16 replica mix missing");
    }
}
