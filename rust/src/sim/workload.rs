//! Seeded open-loop workload generator for the soak simulation.
//!
//! Open-loop means arrival times are drawn independently of service:
//! a slow server falls behind and queues, which is exactly the regime
//! the "heavy traffic from millions of users" north star cares about.
//! Interarrivals are Pareto-distributed (heavy-tailed bursts — long
//! quiet stretches punctuated by packed arrivals), and each arrival is
//! either one eval row for the batcher or a decode stream with seeded
//! prompt length, generation length, and replica wire format.
//!
//! Multi-tenancy (ISSUE 9): with `tenants > 0` every arrival is also
//! tagged with a Zipf-skewed tenant id (tenant 0 hottest — the greedy
//! client the quota layer exists for) and a seeded [`RequestClass`]
//! drawn from `class_mix`. With `tenants == 0` the extra draws are
//! skipped entirely, so legacy seeds reproduce bit-identical streams.

use crate::tenant::RequestClass;
use crate::util::quant::WireFmt;
use crate::util::rng::Rng;

/// What arrived.
#[derive(Debug, Clone, PartialEq)]
pub enum Arrival {
    /// One single-row eval request (batched by `server::BatcherCore`).
    Eval,
    /// One autoregressive decode stream for the scheduler.
    Decode {
        prompt: Vec<i32>,
        steps: usize,
        /// Replica wire of the stream's buddy replication (the
        /// replication cost knob): f32 exact, f16 half-cost lossy.
        replica_wire: WireFmt,
    },
}

/// One arrival at a virtual timestamp (seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadItem {
    pub at: f64,
    pub kind: Arrival,
    /// Originating tenant (always 0 when `tenants == 0`).
    pub tenant: u32,
    /// Priority class (always `Batch` when `tenants == 0`).
    pub class: RequestClass,
}

/// Workload shape knobs.
#[derive(Debug, Clone)]
pub struct WorkloadCfg {
    /// Total arrivals to generate.
    pub requests: usize,
    /// Mean interarrival gap (virtual seconds).
    pub mean_interarrival: f64,
    /// Pareto tail exponent (> 1): smaller = heavier bursts.
    pub tail_alpha: f64,
    /// Fraction of arrivals that are decode streams.
    pub decode_fraction: f64,
    /// Decode vocabulary (prompt tokens drawn from `1..vocab`).
    pub vocab: usize,
    /// Inclusive prompt-length range.
    pub prompt_len: (usize, usize),
    /// Inclusive generated-token range (min >= 1: a zero-step stream
    /// closes with an abort event by contract).
    pub steps: (usize, usize),
    /// Tenants sharing the deployment; 0 = untagged legacy workload
    /// (tenant 0, class Batch, no extra RNG draws).
    pub tenants: usize,
    /// Zipf skew exponent for the tenant draw (> 0): tenant `i` gets
    /// weight `1 / (i + 1)^skew`, so tenant 0 is the hot one.
    pub tenant_skew: f64,
    /// Class mix as (interactive fraction, batch fraction); the
    /// remainder is best-effort.
    pub class_mix: (f64, f64),
}

impl Default for WorkloadCfg {
    fn default() -> WorkloadCfg {
        WorkloadCfg {
            requests: 1000,
            mean_interarrival: 0.02,
            tail_alpha: 1.5,
            decode_fraction: 0.3,
            vocab: 20,
            prompt_len: (3, 8),
            steps: (4, 12),
            tenants: 0,
            tenant_skew: 1.0,
            class_mix: (0.0, 1.0),
        }
    }
}

/// The seeded generator; an iterator over [`WorkloadItem`]s with
/// strictly increasing timestamps.
pub struct WorkloadGen {
    rng: Rng,
    cfg: WorkloadCfg,
    now: f64,
    emitted: usize,
    /// Cumulative (unnormalized) Zipf weights, one per tenant; empty
    /// when tenancy is off.
    zipf_cum: Vec<f64>,
}

impl WorkloadGen {
    pub fn new(seed: u64, cfg: WorkloadCfg) -> WorkloadGen {
        let mut zipf_cum = Vec::with_capacity(cfg.tenants);
        let mut acc = 0.0;
        for i in 0..cfg.tenants {
            acc += 1.0 / ((i + 1) as f64).powf(cfg.tenant_skew.max(0.0));
            zipf_cum.push(acc);
        }
        WorkloadGen { rng: Rng::new(seed), cfg, now: 0.0, emitted: 0,
                      zipf_cum }
    }

    /// Pareto interarrival with the configured mean, capped at 50x so
    /// one tail draw cannot stall the whole soak: scale x_m is chosen
    /// so E[X] = alpha * x_m / (alpha - 1) equals `mean_interarrival`.
    fn interarrival(&mut self) -> f64 {
        let a = self.cfg.tail_alpha;
        let xm = self.cfg.mean_interarrival * (a - 1.0) / a;
        let u = self.rng.f64().max(1e-12);
        (xm / u.powf(1.0 / a)).min(self.cfg.mean_interarrival * 50.0)
    }

    fn draw_tenant(&mut self) -> u32 {
        let total = *self.zipf_cum.last().unwrap();
        let x = self.rng.f64() * total;
        self.zipf_cum.iter().position(|&c| x < c)
            .unwrap_or(self.cfg.tenants - 1) as u32
    }

    fn draw_class(&mut self) -> RequestClass {
        let (fi, fb) = self.cfg.class_mix;
        let x = self.rng.f64();
        if x < fi {
            RequestClass::Interactive
        } else if x < fi + fb {
            RequestClass::Batch
        } else {
            RequestClass::BestEffort
        }
    }
}

impl Iterator for WorkloadGen {
    type Item = WorkloadItem;

    fn next(&mut self) -> Option<WorkloadItem> {
        if self.emitted >= self.cfg.requests {
            return None;
        }
        self.emitted += 1;
        self.now += self.interarrival();
        let kind = if self.rng.chance(self.cfg.decode_fraction) {
            let (lo, hi) = self.cfg.prompt_len;
            let len = self.rng.range(lo, hi + 1);
            let prompt = (0..len)
                .map(|_| self.rng.range(1, self.cfg.vocab) as i32)
                .collect();
            let (slo, shi) = self.cfg.steps;
            let steps = self.rng.range(slo.max(1), shi + 1);
            // CR variety: a third of the streams take the half-cost
            // lossy f16 replica, the rest the exact f32 one
            let replica_wire = if self.rng.chance(0.33) {
                WireFmt::F16
            } else {
                WireFmt::F32
            };
            Arrival::Decode { prompt, steps, replica_wire }
        } else {
            Arrival::Eval
        };
        // tenancy draws come last and only when enabled, so legacy
        // (tenants == 0) RNG streams stay bit-identical to pre-tenancy
        let (tenant, class) = if self.cfg.tenants > 0 {
            (self.draw_tenant(), self.draw_class())
        } else {
            (0, RequestClass::Batch)
        };
        Some(WorkloadItem { at: self.now, kind, tenant, class })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_and_deterministic() {
        let cfg = WorkloadCfg { requests: 200, ..Default::default() };
        let a: Vec<WorkloadItem> =
            WorkloadGen::new(7, cfg.clone()).collect();
        let b: Vec<WorkloadItem> =
            WorkloadGen::new(7, cfg.clone()).collect();
        assert_eq!(a, b);
        let c: Vec<WorkloadItem> = WorkloadGen::new(8, cfg).collect();
        assert_ne!(a, c, "different seeds must differ");
        assert_eq!(a.len(), 200);
        // legacy workloads are untagged
        assert!(a.iter().all(|it| {
            it.tenant == 0 && it.class == RequestClass::Batch
        }));
    }

    #[test]
    fn timestamps_increase_and_tail_is_heavy() {
        let cfg = WorkloadCfg { requests: 5000, ..Default::default() };
        let items: Vec<WorkloadItem> =
            WorkloadGen::new(11, cfg.clone()).collect();
        let mut last = 0.0;
        let mut max_gap: f64 = 0.0;
        for it in &items {
            assert!(it.at > last, "timestamps must strictly increase");
            max_gap = max_gap.max(it.at - last);
            last = it.at;
        }
        // heavy tail: some gap far beyond the mean, but capped
        assert!(max_gap > 4.0 * cfg.mean_interarrival,
                "max gap {max_gap} is not heavy-tailed");
        assert!(max_gap <= 50.0 * cfg.mean_interarrival + 1e-12);
    }

    #[test]
    fn mixes_eval_and_decode_with_valid_shapes() {
        let cfg = WorkloadCfg { requests: 2000, ..Default::default() };
        let items: Vec<WorkloadItem> =
            WorkloadGen::new(3, cfg.clone()).collect();
        let mut decodes = 0;
        let mut f16 = 0;
        for it in &items {
            if let Arrival::Decode { prompt, steps, replica_wire } =
                &it.kind
            {
                decodes += 1;
                assert!((cfg.prompt_len.0..=cfg.prompt_len.1)
                    .contains(&prompt.len()));
                assert!((cfg.steps.0..=cfg.steps.1).contains(steps));
                assert!(prompt.iter().all(|&t| {
                    t >= 1 && (t as usize) < cfg.vocab
                }));
                if *replica_wire == WireFmt::F16 {
                    f16 += 1;
                }
            }
        }
        // fractions in the right ballpark (seeded, not flaky)
        assert!(decodes > 450 && decodes < 750, "decodes {decodes}");
        assert!(f16 > 0 && f16 < decodes, "f16 replica mix missing");
    }

    #[test]
    fn tenancy_off_leaves_legacy_streams_bit_identical() {
        // the same seed with tenancy knobs present-but-off must yield
        // exactly the legacy arrival sequence (times, kinds, shapes)
        let legacy: Vec<WorkloadItem> =
            WorkloadGen::new(13, WorkloadCfg::default()).collect();
        let off = WorkloadCfg { tenant_skew: 2.0, class_mix: (0.5, 0.3),
                                ..Default::default() }; // tenants: 0
        let tagged: Vec<WorkloadItem> =
            WorkloadGen::new(13, off).collect();
        assert_eq!(legacy, tagged);
    }

    #[test]
    fn zipf_tenants_are_skewed_and_classes_mixed() {
        let cfg = WorkloadCfg {
            requests: 4000,
            tenants: 10,
            tenant_skew: 1.2,
            class_mix: (0.2, 0.5),
            ..Default::default()
        };
        let items: Vec<WorkloadItem> =
            WorkloadGen::new(5, cfg.clone()).collect();
        let mut per_tenant = vec![0usize; cfg.tenants];
        let mut per_class = [0usize; 3];
        for it in &items {
            per_tenant[it.tenant as usize] += 1;
            per_class[it.class.index()] += 1;
        }
        // Zipf skew: the hot tenant dominates, everyone shows up
        assert!(per_tenant[0] > 2 * per_tenant[4],
                "tenant skew missing: {per_tenant:?}");
        assert!(per_tenant.iter().all(|&n| n > 0), "{per_tenant:?}");
        // class mix lands near the configured fractions
        let frac = |n: usize| n as f64 / items.len() as f64;
        assert!((frac(per_class[RequestClass::Interactive.index()])
                 - 0.2).abs() < 0.05);
        assert!((frac(per_class[RequestClass::Batch.index()])
                 - 0.5).abs() < 0.05);
        assert!(per_class[RequestClass::BestEffort.index()] > 0);
        // deterministic under tenancy too
        let again: Vec<WorkloadItem> =
            WorkloadGen::new(5, cfg).collect();
        assert_eq!(items, again);
    }
}
