//! Membership churn schedules for the soak simulation: kill and revive
//! workers at virtual timestamps.

use crate::util::rng::Rng;

/// One membership event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnEvent {
    /// The worker's thread dies outright (its transport slot goes dark;
    /// the master discovers it through the gather deadline + probe).
    Kill(usize),
    /// A replacement thread is spawned on the dead slot and re-admitted.
    Revive(usize),
    /// The worker's clock rate changes: its speed multiplier is set to
    /// the carried value (stored as `f64::to_bits` so the event stays
    /// `Eq`/hashable). Models thermal throttling / DVFS on edge devices.
    Throttle(usize, u64),
    /// One directed mesh edge `from -> to` gains the carried extra
    /// delivery delay in seconds (`f64::to_bits`, zero heals the link).
    /// Models a congested / flaky last-hop radio between two edge
    /// devices while the rest of the fleet stays healthy.
    LinkDelay(usize, usize, u64),
    /// The master itself dies (its endpoint goes dark, every byte of
    /// coordinator state is discarded). The HA soak's headline event:
    /// the standby must detect it via gossip quorum and promote — no
    /// worker slot is named because the victim is the coordinator.
    KillMaster,
}

impl ChurnEvent {
    /// Construct a throttle event from a plain speed multiplier.
    pub fn throttle(worker: usize, speed: f64) -> ChurnEvent {
        ChurnEvent::Throttle(worker, speed.to_bits())
    }

    /// Construct a link-delay event from a plain delay in seconds.
    pub fn link_delay(from: usize, to: usize, secs: f64) -> ChurnEvent {
        ChurnEvent::LinkDelay(from, to, secs.to_bits())
    }
}

/// A time-sorted list of churn events, consumed as virtual time passes.
#[derive(Debug, Clone)]
pub struct ChurnSchedule {
    events: Vec<(f64, ChurnEvent)>,
    next: usize,
}

impl ChurnSchedule {
    pub fn new(mut events: Vec<(f64, ChurnEvent)>) -> ChurnSchedule {
        events.sort_by(|a, b| a.0.total_cmp(&b.0));
        ChurnSchedule { events, next: 0 }
    }

    /// No churn.
    pub fn none() -> ChurnSchedule {
        ChurnSchedule::new(Vec::new())
    }

    /// Seeded kill/revive cycles spread over `horizon` virtual seconds:
    /// each cycle picks a victim among `p` workers, kills it partway
    /// into its slot, and revives it before the slot ends — so at most
    /// one device is dead at a time (harsher overlapping shapes are the
    /// chaos/elastic suites' job; the soak pins throughput and
    /// recovery under *sustained* single-failure churn).
    pub fn cycles(seed: u64, p: usize, horizon: f64, cycles: usize)
                  -> ChurnSchedule {
        assert!(p > 0 && cycles > 0 && horizon > 0.0);
        let mut rng = Rng::new(seed);
        let slot = horizon / cycles as f64;
        let mut events = Vec::with_capacity(2 * cycles);
        for c in 0..cycles {
            let victim = rng.below(p);
            let t0 = (c as f64 + 0.2 + 0.3 * rng.f64()) * slot;
            let t1 = t0 + (0.2 + 0.2 * rng.f64()) * slot;
            events.push((t0, ChurnEvent::Kill(victim)));
            events.push((t1, ChurnEvent::Revive(victim)));
        }
        ChurnSchedule::new(events)
    }

    /// Timestamp of the next unconsumed event.
    pub fn next_at(&self) -> Option<f64> {
        self.events.get(self.next).map(|(t, _)| *t)
    }

    /// Consume and return every event due at or before `now`.
    pub fn pop_due(&mut self, now: f64) -> Vec<ChurnEvent> {
        let mut due = Vec::new();
        while let Some(&(t, ev)) = self.events.get(self.next) {
            if t > now {
                break;
            }
            due.push(ev);
            self.next += 1;
        }
        due
    }

    pub fn remaining(&self) -> usize {
        self.events.len() - self.next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sorts_and_pops_in_order() {
        let mut s = ChurnSchedule::new(vec![
            (2.0, ChurnEvent::Revive(1)),
            (1.0, ChurnEvent::Kill(1)),
            (3.0, ChurnEvent::Kill(0)),
        ]);
        assert_eq!(s.next_at(), Some(1.0));
        assert_eq!(s.pop_due(0.5), vec![]);
        assert_eq!(s.pop_due(2.0),
                   vec![ChurnEvent::Kill(1), ChurnEvent::Revive(1)]);
        assert_eq!(s.remaining(), 1);
        assert_eq!(s.pop_due(10.0), vec![ChurnEvent::Kill(0)]);
        assert_eq!(s.next_at(), None);
        assert!(ChurnSchedule::none().next_at().is_none());
    }

    #[test]
    fn throttle_events_carry_exact_speed_bits() {
        let ev = ChurnEvent::throttle(2, 0.25);
        assert_eq!(ev, ChurnEvent::Throttle(2, 0.25_f64.to_bits()));
        let mut s = ChurnSchedule::new(vec![(4.0, ev)]);
        match s.pop_due(5.0)[0] {
            ChurnEvent::Throttle(w, bits) => {
                assert_eq!((w, f64::from_bits(bits)), (2, 0.25));
            }
            other => panic!("expected throttle, got {other:?}"),
        }
    }

    #[test]
    fn link_delay_events_carry_exact_delay_bits() {
        let ev = ChurnEvent::link_delay(0, 2, 1.5);
        assert_eq!(ev, ChurnEvent::LinkDelay(0, 2, 1.5_f64.to_bits()));
        let mut s = ChurnSchedule::new(vec![(4.0, ev)]);
        match s.pop_due(5.0)[0] {
            ChurnEvent::LinkDelay(f, t, bits) => {
                assert_eq!((f, t, f64::from_bits(bits)), (0, 2, 1.5));
            }
            other => panic!("expected link delay, got {other:?}"),
        }
    }

    #[test]
    fn cycles_kill_then_revive_one_at_a_time() {
        let s = ChurnSchedule::cycles(42, 4, 20.0, 3);
        assert_eq!(s.events.len(), 6);
        let mut dead: Option<usize> = None;
        for &(t, ev) in &s.events {
            assert!(t > 0.0 && t < 20.0 + 10.0);
            match ev {
                ChurnEvent::Kill(w) => {
                    assert!(dead.is_none(),
                            "two devices dead at once");
                    dead = Some(w);
                }
                ChurnEvent::Revive(w) => {
                    assert_eq!(dead, Some(w), "revive mismatch");
                    dead = None;
                }
                ChurnEvent::Throttle(..) | ChurnEvent::LinkDelay(..)
                | ChurnEvent::KillMaster => {
                    panic!("cycles() only emits kill/revive")
                }
            }
        }
        assert!(dead.is_none());
        // deterministic per seed
        let again = ChurnSchedule::cycles(42, 4, 20.0, 3);
        assert_eq!(s.events, again.events);
    }
}
