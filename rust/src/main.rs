//! `prism` — CLI entry point for the PRISM distributed-inference runtime.
//!
//! Subcommands:
//!   info                         manifest / artifact summary
//!   eval                         run a dataset through a strategy, print
//!                                the paper metric + measured comm bytes
//!   latency                      Fig.5-style latency at one bandwidth
//!   serve                        threaded master/worker serving demo
//!   decode                       continuous-batching decode-stream demo
//!                                (incremental KV-cache sessions)
//!   worker --listen ADDR         TCP block-execution worker process
//!
//! Common flags: --artifacts DIR (default ./artifacts), --model,
//! --dataset, --mode single|voltage|prism, --p, --l, --cr, --kernel
//! xla|pallas, --limit N, --finetuned, --no-dup.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use prism::cli::Args;
use prism::coordinator::{Mode, Runner};
use prism::data::Dataset;
use prism::eval::{evaluate, EvalOpts};
use prism::model::{comm, flops, paper};
use prism::net::LinkModel;
use prism::runtime::{Engine, Manifest, WeightSet};
use prism::server;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "info" => cmd_info(&args),
        "eval" => cmd_eval(&args),
        "latency" => cmd_latency(&args),
        "serve" => server::cmd_serve(&args),
        "decode" => server::cmd_decode(&args),
        "worker" => server::cmd_worker(&args),
        "remote-eval" => cmd_remote_eval(&args),
        "" | "help" | "--help" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `prism help`)"),
    }
}

const HELP: &str = "prism — distributed Transformer inference at the edge
commands: info | eval | latency | serve | decode | worker
examples:
  prism info
  prism eval --model vit --dataset synth10 --mode prism --p 2 --l 6
  prism eval --model gpt2 --dataset text8p --mode prism --p 3 --cr 10
  prism latency --model vit --mode prism --p 3 --l 3 --bandwidth 200
  prism serve --model vit --dataset synth10 --p 2 --l 6 --requests 64 \\
        --gather-timeout-ms 30000
  prism serve --model vit --dataset synth10 --l 6 --requests 64 \\
        --workers 127.0.0.1:7070,127.0.0.1:7071,127.0.0.1:7072
  prism serve --model vit --dataset synth10 --p 2 --l 6 --requests 64 \\
        --tenants 8 --quota 50 --shed-cap 256 --class interactive
  prism decode --sessions 4 --steps 32 --p 2 --l 4 --wire f16
  prism decode --sessions 4 --replicate --replica-wire f16 \\
        --fail-device 0 --fail-after 8 --rejoin-after 16
  prism worker --listen 127.0.0.1:7070
  prism remote-eval --workers 127.0.0.1:7070,127.0.0.1:7071 \\
        --model vit --mode prism --p 2 --l 6 --limit 64
elastic membership: when a worker blows the gather deadline the master
re-plans over the survivors (Eq. 16 re-picks L for P') and keeps the
remaining parallelism, degrading to single-device only at P'=1; decode
streams with --replicate survive --fail-device via CacheSync migration
and --rejoin-after restores the full geometry (tests/chaos.rs and
tests/elastic.rs hold the fault and membership matrices)
multi-tenant front door: `--tenants N` arms per-tenant token-bucket
admission (`--quota` req/s, `--quota-burst`) and class-aware overload
shedding (`--shed-cap` is the best-effort load cap; batch and
interactive shed at 2x and 4x), with generated traffic tagged by
`--class interactive|batch|best-effort`; the serve stats line reports
per-class admitted/shed counts and latency percentiles (the full
matrix lives in tests/tenants.rs on the virtual clock)
mesh serving: `prism serve --workers host:port,...` drives real
`prism worker --listen` processes — Segment-Means exchanges go peer to
peer over the worker TCP mesh (the master keeps only the control
plane), a killed worker triggers the same Eq. 16 re-plan across
processes, and a restarted `prism worker` on a dead address is
re-admitted at the next batch boundary";

pub fn manifest_from(args: &Args) -> Result<Arc<Manifest>> {
    let root = PathBuf::from(args.str_or("artifacts", "artifacts"));
    Ok(Arc::new(Manifest::load(&root)?))
}

/// Resolve (model, dataset, weight tag) with per-model defaults.
pub fn resolve_workload(args: &Args, m: &Manifest)
                        -> Result<(String, String, String)> {
    let model = args.str_or("model", "vit");
    let dataset = args.str_or("dataset", match model.as_str() {
        "vit" => "synth10",
        "bert" => "sst2p",
        _ => "text8p",
    });
    let mut tag = match model.as_str() {
        "vit" => format!("vit_{dataset}"),
        other => other.to_string(),
    };
    if args.bool("finetuned") {
        tag = format!("{tag}_ft");
    }
    if let Some(w) = args.flags.get("weights") {
        tag = w.clone();
    }
    if !m.weights.contains_key(&tag) {
        bail!("no weight set '{tag}' in manifest (have: {:?})",
              m.weights.keys().collect::<Vec<_>>());
    }
    Ok((model, dataset, tag))
}

/// Resolve the strategy from --mode / --p / --l / --cr (the shared
/// parser — `Mode::parse` — also used by `prism serve`).
pub fn resolve_mode(args: &Args, n: usize) -> Result<Mode> {
    Mode::parse(args, n, 0)
}

fn cmd_info(args: &Args) -> Result<()> {
    let m = manifest_from(args)?;
    let engine = Engine::new(m.clone())?;
    println!("platform        : {}", engine.platform());
    println!("models          : {}",
             m.models.keys().cloned().collect::<Vec<_>>().join(", "));
    println!("weight sets     : {}",
             m.weights.keys().cloned().collect::<Vec<_>>().join(", "));
    println!("executables     : {}", m.executables.len());
    println!("variants        : {}", m.variants.len());
    println!("eval batch      : {}", m.eval_batch);
    for (name, cfg) in &m.models {
        let dims = paper::dims_from_cfg(cfg);
        let pdims = paper::paper_dims(name);
        println!(
            "  {name}: N={} D={} H={} layers={} causal={} | tiny {:.3} \
             GFLOPs, paper-scale {:.2} GFLOPs",
            cfg.n, cfg.d, cfg.heads, cfg.layers, cfg.causal,
            flops::single_total(&dims) / 1e9,
            pdims.map(|d| flops::single_total(&d) / 1e9).unwrap_or(0.0),
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let m = manifest_from(args)?;
    let (model, dataset, tag) = resolve_workload(args, &m)?;
    let cfg = m.model(&model)?.clone();
    let mode = resolve_mode(args, cfg.n)?;
    let flavor = args.str_or("kernel", "xla");
    let limit = args.usize_or("limit", 0)?;

    let mut runner = Runner::new(m.clone(), &flavor)?;
    let ws = WeightSet::load(&m, &tag)?;
    let ds = Dataset::load(&m.root, &dataset)?;
    if ds.model != model {
        bail!("dataset '{dataset}' belongs to model '{}'", ds.model);
    }
    println!("eval {model}/{dataset} weights={tag} mode={:?} kernel={flavor}",
             mode);
    let res = evaluate(&mut runner, &ws, &ds, &EvalOpts { mode, limit })?;
    println!("{:>10} : {:.4}", res.metric_name, res.metric);
    println!("{:>10} : {}", "samples", res.samples);
    println!("{:>10} : {:.2}s total, {:.1}ms compute/batch", "time",
             res.total_secs, res.trace.total_compute_secs() * 1e3);
    if mode.p() > 1 {
        let bytes = res.trace.device_exchange_bytes(0);
        println!("{:>10} : {} B/device across {} layers", "exchange",
                 bytes, cfg.layers);
        if let Mode::Prism { p, l, .. } = mode {
            println!("{:>10} : CR={:.2} PDPLC={} tokens, comm speed-up \
                      {:.2}% vs Voltage", "comm",
                     prism::coordinator::plan::effective_cr(cfg.n, p, l),
                     comm::pdplc_tokens_prism(p, l),
                     comm::comm_speedup(cfg.n, p, l) * 100.0);
        }
    }
    Ok(())
}

fn cmd_latency(args: &Args) -> Result<()> {
    let m = manifest_from(args)?;
    let (model, dataset, tag) = resolve_workload(args, &m)?;
    let cfg = m.model(&model)?.clone();
    let mode = resolve_mode(args, cfg.n)?;
    let flavor = args.str_or("kernel", "xla");
    let bw = args.f64_or("bandwidth", 200.0)?;
    let lat = args.f64_or("link-latency-ms", 2.0)?;
    let reps = args.usize_or("reps", 3)?;

    let mut runner = Runner::new(m.clone(), &flavor)?;
    let ws = WeightSet::load(&m, &tag)?;
    let ds = Dataset::load(&m.root, &dataset)?;
    let batch = m.latency_batch;
    // single-query latency (paper Fig. 5 uses batch size 1)
    let raw = match ds.kind {
        prism::data::DatasetKind::Vision => ds.x.slice0(0, batch)?,
        _ => {
            let n1 = ds.x.shape[1];
            let row = ds.x.slice0(0, batch)?;
            let take = cfg.n.min(n1);
            let mut ids = Vec::with_capacity(batch * cfg.n);
            for b in 0..batch {
                let r = &row.i32s()?[b * n1..b * n1 + take];
                ids.extend_from_slice(r);
                ids.extend(std::iter::repeat(0).take(cfg.n - take));
            }
            prism::runtime::Tensor::from_i32(vec![batch, cfg.n], ids)?
        }
    };
    let task = if cfg.causal { "lm".to_string() } else { dataset.clone() };
    let mut best = f64::INFINITY;
    let mut trace = None;
    for _ in 0..reps.max(1) {
        let (_, t) = runner.forward(&model, &ws, &task, &raw, mode)?;
        if t.total_compute_secs() < best {
            best = t.total_compute_secs();
            trace = Some(t);
        }
    }
    let trace = trace.unwrap();
    let link = LinkModel::new(bw, lat);
    println!("latency {model} mode={mode:?} bw={bw} Mbps link-lat={lat} ms \
              batch={batch}");
    println!("  compute  : {:.2} ms", trace.total_compute_secs() * 1e3);
    println!("  end2end  : {:.2} ms (modeled)",
             trace.latency_secs(link) * 1e3);
    Ok(())
}

// `prism worker --listen` lives in `server::cmd_worker`: one listener
// serves both the mesh serving protocol (`prism serve --workers`) and
// the legacy block-execution RPC (`prism remote-eval`), dispatched on
// the first frame.

/// Distributed evaluation over TCP workers (start them first with
/// `prism worker --listen ...`). Embed/head run locally; blocks run on
/// the remote devices; accuracy must match local `prism eval` exactly.
fn cmd_remote_eval(args: &Args) -> Result<()> {
    use prism::coordinator::RemoteCoordinator;
    use prism::eval::metrics::argmax_rows;
    let m = manifest_from(args)?;
    let (model, dataset, tag) = resolve_workload(args, &m)?;
    let cfg = m.model(&model)?.clone();
    let mode = resolve_mode(args, cfg.n)?;
    let addrs: Vec<String> = args
        .req("workers")?
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let flavor = args.str_or("kernel", "xla");
    let limit = args.usize_or("limit", 64)?;
    let batch = m.eval_batch;

    let mut engine = Engine::new(m.clone())?;
    let ws = WeightSet::load(&m, &tag)?;
    let ds = prism::data::Dataset::load(&m.root, &dataset)?;
    let mut coord = RemoteCoordinator::connect(m.clone(), &addrs,
                                               &flavor)?;
    let embed_name = m.embed_name(&model, batch);
    let task = if cfg.causal { "lm".to_string() } else { dataset.clone() };
    let head_name = m.head_name(&model, &task, batch);

    let total = ds.count().min(if limit == 0 { ds.count() } else { limit });
    let y = ds.y.as_ref().context("labels required")?;
    let mut hits = 0usize;
    let mut seen = 0usize;
    let mut i = 0;
    while i + batch <= total {
        let raw = ds.x.slice0(i, i + batch)?;
        let x = engine.run(&embed_name, &ws, 0, &[&raw])?.remove(0);
        let out = coord.blocks(&model, &tag, &x, mode)?;
        let logits = engine.run(&head_name, &ws, 0, &[&out])?.remove(0);
        let classes = *logits.shape.last().unwrap();
        let preds = argmax_rows(logits.f32s()?, classes);
        for (r, pred) in preds.iter().enumerate().take(batch) {
            let t = match &y.data {
                prism::runtime::TensorData::I32(v) => v[i + r] as usize,
                prism::runtime::TensorData::F32(v) => v[i + r] as usize,
            };
            hits += (*pred == t) as usize;
            seen += 1;
        }
        i += batch;
    }
    let (sent, recv) = coord.bytes();
    coord.shutdown()?;
    println!("remote-eval {model}/{dataset} over {} workers: acc {:.4} \
              ({seen} samples), rpc bytes sent {sent} recv {recv}",
             addrs.len(), hits as f64 / seen.max(1) as f64);
    Ok(())
}
