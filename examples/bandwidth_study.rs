//! Bandwidth study: where does distributed inference beat a single edge
//! device, and how does the compression rate move the crossover?
//!
//!     make artifacts && cargo run --release --example bandwidth_study
//!
//! Extends Fig. 5: sweeps bandwidth × CR for ViT (P = 2, 3), prints the
//! modeled end-to-end latency and the minimum bandwidth at which each
//! strategy breaks even with single-device inference, plus the effect of
//! broadcast (the paper's footnote: broadcast would further cut PRISM's
//! exchange cost for P > 2).

use anyhow::Result;
use prism::bench_util::require_artifacts;
use prism::coordinator::plan::effective_cr;
use prism::coordinator::{Mode, RunTrace, Runner};
use prism::data::Dataset;
use prism::metrics::report::{f2, Table};
use prism::net::LinkModel;
use prism::runtime::WeightSet;

fn best_trace(runner: &mut Runner, ws: &WeightSet, raw: &prism::runtime::Tensor,
              mode: Mode) -> Result<RunTrace> {
    let mut best: Option<RunTrace> = None;
    for _ in 0..5 {
        let (_, t) = runner.forward("vit", ws, "synth10", raw, mode)?;
        if best
            .as_ref()
            .map(|b| t.total_compute_secs() < b.total_compute_secs())
            .unwrap_or(true)
        {
            best = Some(t);
        }
    }
    Ok(best.unwrap())
}

fn main() -> Result<()> {
    let Some(manifest) = require_artifacts() else { return Ok(()) };
    let mut runner = Runner::new(manifest.clone(), "xla")?;
    let ws = WeightSet::load(&manifest, "vit_synth10")?;
    let ds = Dataset::load(&manifest.root, "synth10")?;
    let raw = ds.x.slice0(0, manifest.latency_batch)?;

    // calibrate this host and model everything at ViT-Base scale: at the
    // tiny executables' ~10 ms of compute, link latency dominates and
    // *nothing* breaks even (see fig5_latency's auxiliary table) — the
    // regime the paper studies is seconds of compute.
    use prism::model::paper::{dims_from_cfg, VIT_BASE};
    use prism::model::predict::{calibrate_gflops, paper_trace};
    let cfg = manifest.model("vit")?.clone();
    let measured = best_trace(&mut runner, &ws, &raw, Mode::Single)?;
    let host = calibrate_gflops(&dims_from_cfg(&cfg),
                                manifest.latency_batch, Mode::Single,
                                &measured);
    let n = VIT_BASE.n;
    let single = paper_trace(&VIT_BASE, Mode::Single, host);
    println!("calibrated host: {host:.1} GFLOPS; single-device \
              (ViT-Base scale): {:.2} s compute\n",
             single.total_compute_secs());

    let mut table = Table::new(
        "break-even bandwidth vs single device (ViT-Base scale, batch 1)",
        &["strategy", "CR", "compute(s)", "break-even(Mbps)",
          "latency@100Mbps", "latency@1Gbps", "bcast@100Mbps"],
    );
    let mut cases: Vec<(String, Mode)> = vec![
        ("voltage p=2".into(), Mode::Voltage { p: 2 }),
        ("voltage p=3".into(), Mode::Voltage { p: 3 }),
    ];
    // paper-scale landmark budgets (N = 197)
    for (p, ls) in [(2usize, vec![10usize, 20, 30]), (3, vec![10, 20])] {
        for l in ls {
            cases.push((format!("prism p={p} l={l}"),
                        Mode::Prism { p, l, duplicated: true }));
        }
    }
    for (label, mode) in cases {
        let trace = paper_trace(&VIT_BASE, mode, host);
        // binary-search the bandwidth where this strategy == single
        let breakeven = {
            let (mut lo, mut hi) = (1.0f64, 100_000.0f64);
            let single_secs = single.total_compute_secs();
            if trace.latency_secs(LinkModel::new(hi, 2.0)) > single_secs {
                f64::INFINITY
            } else {
                for _ in 0..60 {
                    let mid = 0.5 * (lo + hi);
                    if trace.latency_secs(LinkModel::new(mid, 2.0))
                        > single_secs
                    {
                        lo = mid;
                    } else {
                        hi = mid;
                    }
                }
                hi
            }
        };
        let cr = match mode {
            Mode::Prism { p, l, .. } => f2(effective_cr(n, p, l)),
            _ => "-".into(),
        };
        let mut bc = LinkModel::new(100.0, 2.0);
        bc.broadcast = true;
        table.row(vec![
            label,
            cr,
            format!("{:.2}", trace.total_compute_secs()),
            if breakeven.is_finite() {
                format!("{breakeven:.0}")
            } else {
                "never".into()
            },
            format!("{:.2}",
                    trace.latency_secs(LinkModel::new(100.0, 2.0))),
            format!("{:.2}",
                    trace.latency_secs(LinkModel::new(1000.0, 2.0))),
            format!("{:.2}", trace.latency_secs(bc)),
        ]);
    }
    table.print();
    println!("\nReading: PRISM's break-even bandwidth sits far below \
              Voltage's (less data per exchange); higher CR lowers it \
              further; broadcast helps P=3 the most, exactly as the \
              paper's unicast-assumption footnote predicts.");
    Ok(())
}
