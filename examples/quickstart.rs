//! Quickstart: classify one batch of images with PRISM distributed
//! inference (P = 2 edge devices, Segment-Means exchange) and compare
//! against the single-device result.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Uses the **pallas** flavor artifacts — the Layer-1 Pallas kernel
//! (interpret-mode on CPU) is on the hot path here, proving the full
//! three-layer composition: rust coordinator -> AOT HLO -> Pallas kernel.

use anyhow::Result;
use prism::bench_util::require_artifacts;
use prism::coordinator::{Mode, Runner};
use prism::data::Dataset;
use prism::eval::metrics::argmax_rows;
use prism::model::comm;
use prism::net::LinkModel;
use prism::runtime::WeightSet;

fn main() -> Result<()> {
    let Some(manifest) = require_artifacts() else { return Ok(()) };
    let mut runner = Runner::new(manifest.clone(), "pallas")?;
    let ws = WeightSet::load(&manifest, "vit_synth10")?;
    let ds = Dataset::load(&manifest.root, "synth10")?;
    let cfg = manifest.model("vit")?.clone();

    let batch = manifest.eval_batch;
    let raw = ds.x.slice0(0, batch)?;
    let labels = &ds.y.as_ref().unwrap().i32s()?[..batch];

    println!("PRISM quickstart — ViT ({} tokens, {} layers) on {} images",
             cfg.n, cfg.layers, batch);

    // 1) distributed: 2 devices, 6 landmarks each (CR ≈ 5.4)
    let mode = Mode::Prism { p: 2, l: 6, duplicated: true };
    let (logits, trace) = runner.forward("vit", &ws, "synth10", &raw,
                                         mode)?;
    let pred = argmax_rows(logits.f32s()?, ds.classes);

    // 2) single-device reference
    let (ref_logits, _) =
        runner.forward("vit", &ws, "synth10", &raw, Mode::Single)?;
    let ref_pred = argmax_rows(ref_logits.f32s()?, ds.classes);

    let agree = pred.iter().zip(&ref_pred).filter(|(a, b)| a == b).count();
    let correct = pred
        .iter()
        .zip(labels)
        .filter(|(p, t)| **p == **t as usize)
        .count();

    println!("  predictions        : {pred:?}");
    println!("  labels             : {labels:?}");
    println!("  correct            : {correct}/{batch}");
    println!("  agree w/ 1-device  : {agree}/{batch}");
    println!("  exchange payload   : {} B/device/layer ({} tokens vs {} \
              under Voltage)",
             comm::bytes_prism(cfg.d, 2, 6),
             comm::pdplc_tokens_prism(2, 6),
             comm::pdplc_tokens_voltage(cfg.n, 2));
    println!("  comm speed-up      : {:.1}% vs Voltage",
             comm::comm_speedup(cfg.n, 2, 6) * 100.0);
    println!("  compute (measured) : {:.1} ms/batch",
             trace.total_compute_secs() * 1e3);
    println!("  e2e @200 Mbps      : {:.1} ms (modeled)",
             trace.latency_secs(LinkModel::new(200.0, 2.0)) * 1e3);
    Ok(())
}
