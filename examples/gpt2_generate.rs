//! Distributed autoregressive generation with the partition-aware causal
//! mask (paper §IV-D), two ways:
//!
//! 1. **Incremental decode** (always runs, artifact-free): a
//!    `decode::DecodeSession` keeps per-device KV caches and broadcasts
//!    one Segment-Means delta row per layer per token, verified here to
//!    emit the *identical* token stream as full recompute while
//!    exchanging ~2L x fewer bytes per token.
//! 2. **AOT full recompute** (when `make artifacts` has run): the
//!    original trained char-GPT path over `Runner`, now through the
//!    shared `Runner::greedy_decode` baseline.
//!
//!     cargo run --release --example gpt2_generate
//!
//! Both use `decode::window`: the AOT shape stays fixed at N, right-pads
//! with id 0 (safe under the causal mask), and reads logits at the
//! frontier row.

use std::sync::Arc;

use anyhow::Result;
use prism::bench_util::require_artifacts;
use prism::coordinator::{Mode, Runner};
use prism::decode::{full_recompute_bytes_per_token, DecodeSession, RefCfg,
                    RefGpt};
use prism::runtime::WeightSet;
use prism::util::quant::WireFmt;

/// Charset must mirror python/compile/data.py (0 = pad).
const CHARSET: &str =
    " ,.ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz";

fn encode(s: &str) -> Vec<i32> {
    s.chars()
        .map(|c| CHARSET.find(c).map(|i| i as i32 + 1).unwrap_or(1))
        .collect()
}

fn decode_chars(ids: &[i32]) -> String {
    ids.iter()
        .map(|&id| {
            if id == 0 {
                '·'
            } else {
                CHARSET.chars().nth(id as usize - 1).unwrap_or('?')
            }
        })
        .collect()
}

/// Part 1: incremental vs full-recompute decode on the deterministic
/// reference backend — the decode subsystem's correctness + bytes story.
fn incremental_demo(prompt: &str, steps: usize) -> Result<()> {
    let cfg = RefCfg {
        vocab: CHARSET.len() + 1,
        n: 64,
        d: 32,
        heads: 4,
        layers: 4,
        ffn: 64,
    };
    let (p, l) = (2, 4);
    let model = Arc::new(RefGpt::tiny(23, cfg)?);
    let ids = encode(prompt);
    println!("== incremental decode (reference backend, N={} P={p} L={l}) \
              ==", cfg.n);

    let (full, full_bytes) =
        model.greedy_decode_full(&ids, steps, p, l, WireFmt::F32)?;
    let mut sess = DecodeSession::new(model.clone(), p, l, WireFmt::F32)?;
    sess.prefill(&ids)?;
    let inc: Vec<i32> =
        (0..steps).map(|_| sess.generate_next()).collect::<Result<_>>()?;
    let stats = sess.stats();

    println!("  full    : {prompt}{}", decode_chars(&full));
    println!("  incr    : {prompt}{}", decode_chars(&inc));
    let agree = inc.iter().zip(&full).take_while(|(a, b)| a == b).count();
    println!("  agreement          : {agree}/{steps} tokens identical");
    assert_eq!(inc, full, "incremental decode must match full recompute");

    let inc_bytes = stats.wire_bytes();
    println!("  bytes/token        : incremental {:.0} (prefill incl.) vs \
              full recompute {} ({:.1}x less overall)",
             stats.bytes_per_generated(),
             full_recompute_bytes_per_token(cfg.layers, p, l, cfg.d,
                                            WireFmt::F32),
             full_bytes as f64 / inc_bytes as f64);
    println!("  kv cache           : {} B resident across {} devices",
             sess.cache_bytes(), p);
    println!("  seg deltas         : {} messages, {} B",
             stats.delta_messages, stats.delta_bytes);
    Ok(())
}

/// Part 2: the trained char-GPT over AOT artifacts (full recompute; the
/// incremental AOT step needs (1, 1, D) executables — see decode/mod.rs).
fn aot_demo(prompt: &str, steps: usize) -> Result<()> {
    let Some(manifest) = require_artifacts() else { return Ok(()) };
    let cfg = manifest.model("gpt2")?.clone();
    let mut runner = Runner::new(manifest.clone(), "xla")?;
    let ws = WeightSet::load(&manifest, "gpt2")?;
    println!("== AOT char-GPT (full recompute, N={}, P=2, L=16, CR=4) ==",
             cfg.n);

    let ids = encode(prompt);
    let dist_mode = Mode::Prism { p: 2, l: 16, duplicated: true };
    let (dist, dist_bytes) =
        runner.greedy_decode("gpt2", &ws, &ids, steps, dist_mode)?;
    println!("  prism  (2 devices) : {prompt}{}", decode_chars(&dist));
    println!("  exchanged          : {} B total, {:.0} B/token",
             dist_bytes, dist_bytes as f64 / steps as f64);

    let (single, _) =
        runner.greedy_decode("gpt2", &ws, &ids, steps, Mode::Single)?;
    println!("  single (1 device)  : {prompt}{}", decode_chars(&single));

    let agree = dist
        .iter()
        .zip(&single)
        .take_while(|(a, b)| a == b)
        .count();
    println!("  agreement          : first {agree}/{steps} generated \
              chars identical");
    println!("  (CR=4 compresses the cross-device context; token-level \
              divergence beyond the prefix is the accuracy/communication \
              trade-off of Table VI, not a masking bug — Voltage mode \
              reproduces single-device decoding exactly.)");

    // sanity: voltage (lossless partitioning) must match single exactly
    let (voltage, _) = runner.greedy_decode("gpt2", &ws, &ids, steps,
                                            Mode::Voltage { p: 2 })?;
    println!("  voltage == single  : {}", voltage == single);
    Ok(())
}

fn main() -> Result<()> {
    let prompt = "the old bridge ";
    println!("gpt2_generate — distributed causal decoding");
    println!("  prompt: {prompt:?}\n");
    incremental_demo(prompt, 40)?;
    println!();
    aot_demo(prompt, 60)
}
