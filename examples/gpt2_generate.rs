//! Distributed autoregressive generation with the partition-aware causal
//! mask (paper §IV-D): greedy-decode text from the tiny char-GPT while the
//! sequence is split across P = 2 devices exchanging Segment Means.
//!
//!     make artifacts && cargo run --release --example gpt2_generate
//!
//! Because the causal mask guarantees position t ignores everything after
//! t, right-padding is safe: we keep the AOT shape fixed at N = 128 and
//! read logits at the current frontier. The same prompt is also decoded
//! single-device to show the two causal paths agree.

use anyhow::Result;
use prism::bench_util::require_artifacts;
use prism::coordinator::{Mode, Runner};
use prism::runtime::{Tensor, WeightSet};

/// Charset must mirror python/compile/data.py (0 = pad).
const CHARSET: &str =
    " ,.ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz";

fn encode(s: &str) -> Vec<i32> {
    s.chars()
        .map(|c| CHARSET.find(c).map(|i| i as i32 + 1).unwrap_or(1))
        .collect()
}

fn decode_char(id: usize) -> char {
    if id == 0 {
        '·'
    } else {
        CHARSET.chars().nth(id - 1).unwrap_or('?')
    }
}

fn generate(runner: &mut Runner, ws: &WeightSet, mode: Mode, prompt: &str,
            steps: usize, n: usize, vocab: usize) -> Result<String> {
    let mut ids = encode(prompt);
    let start = ids.len();
    for _ in 0..steps {
        let frontier = ids.len().min(n) - 1;
        let mut padded = ids.clone();
        padded.resize(n, 0); // safe under the causal mask
        if ids.len() > n {
            padded.copy_from_slice(&ids[ids.len() - n..]);
        }
        let raw = Tensor::from_i32(vec![1, n], padded)?;
        let (logits, _) = runner.forward("gpt2", ws, "lm", &raw, mode)?;
        let row = &logits.f32s()?[frontier * vocab..(frontier + 1) * vocab];
        // greedy, but never emit pad
        let mut best = 1;
        for (i, v) in row.iter().enumerate().skip(1) {
            if *v > row[best] {
                best = i;
            }
        }
        ids.push(best as i32);
    }
    Ok(ids[start..]
        .iter()
        .map(|&i| decode_char(i as usize))
        .collect())
}

fn main() -> Result<()> {
    let Some(manifest) = require_artifacts() else { return Ok(()) };
    let cfg = manifest.model("gpt2")?.clone();
    let mut runner = Runner::new(manifest.clone(), "xla")?;
    let ws = WeightSet::load(&manifest, "gpt2")?;

    let prompt = "the old bridge ";
    let steps = 60;
    println!("gpt2_generate — distributed causal decoding (N={}, P=2, \
              L=16, CR=4)", cfg.n);
    println!("  prompt: {prompt:?}");

    let dist_mode = Mode::Prism { p: 2, l: 16, duplicated: true };
    let dist = generate(&mut runner, &ws, dist_mode, prompt, steps, cfg.n,
                        cfg.vocab)?;
    println!("  prism  (2 devices) : {prompt}{dist}");

    let single = generate(&mut runner, &ws, Mode::Single, prompt, steps,
                          cfg.n, cfg.vocab)?;
    println!("  single (1 device)  : {prompt}{single}");

    let agree = dist
        .chars()
        .zip(single.chars())
        .take_while(|(a, b)| a == b)
        .count();
    println!("  agreement          : first {agree}/{steps} generated \
              chars identical");
    println!("  (CR=4 compresses the cross-device context; token-level \
              divergence beyond the prefix is the accuracy/communication \
              trade-off of Table VI, not a masking bug — Voltage mode \
              reproduces single-device decoding exactly.)");

    // sanity: voltage (lossless partitioning) must match single exactly
    let voltage = generate(&mut runner, &ws, Mode::Voltage { p: 2 },
                           prompt, steps, cfg.n, cfg.vocab)?;
    println!("  voltage == single  : {}", voltage == single);
    Ok(())
}
