//! End-to-end serving driver (the repo's validation workload): load the
//! trained tiny ViT, start the threaded master/worker runtime (P = 2
//! PRISM devices, dynamic batcher, mpsc mesh), push a Poisson stream of
//! single-image requests through it, and report latency percentiles,
//! throughput, and online accuracy.
//!
//!     make artifacts && cargo run --release --example vit_serving
//!
//! Flags via env: PRISM_REQUESTS (default 192), PRISM_RATE (default 300/s).
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use anyhow::Result;
use prism::bench_util::require_artifacts;
use prism::coordinator::Mode;
use prism::data::Dataset;
use prism::eval::metrics::argmax_rows;
use prism::metrics::Histogram;
use prism::runtime::WeightSet;
use prism::server::{Request, Response, ServeConfig, Server};
use prism::util::rng::Rng;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() -> Result<()> {
    let Some(manifest) = require_artifacts() else { return Ok(()) };
    let n_requests = env_usize("PRISM_REQUESTS", 192);
    let rate = env_usize("PRISM_RATE", 50) as f64;
    let ds = Dataset::load(&manifest.root, "synth10")?;
    let _ = WeightSet::load(&manifest, "vit_synth10")?; // fail fast

    let cfg = ServeConfig {
        model: "vit".into(),
        task: "synth10".into(),
        weights: "vit_synth10".into(),
        mode: Mode::Prism { p: 2, l: 6, duplicated: true },
        flavor: "xla".into(),
        flush_after: Duration::from_millis(4),
        pace: None,
    };
    println!("vit_serving — threaded PRISM serving (P=2, L=6, batch {}), \
              {} requests @ ~{:.0}/s Poisson",
             manifest.eval_batch, n_requests, rate);
    let server = Server::start(manifest.clone(), cfg)?;

    let (tx, rx) = channel::<Response>();
    let mut rng = Rng::new(42);
    let mut truth = vec![0usize; n_requests];
    let t0 = Instant::now();
    let feeder = {
        let submitter = server.submitter();
        let labels = ds.y.as_ref().unwrap().i32s()?.to_vec();
        let x = ds.x.clone();
        let mut truth_fill: Vec<usize> = Vec::with_capacity(n_requests);
        std::thread::spawn(move || -> Result<Vec<usize>> {
            for id in 0..n_requests {
                let i = rng.below(labels.len());
                truth_fill.push(labels[i] as usize);
                submitter.submit(Request::eval(x.slice0(i, i + 1)?)
                                     .id(id as u64)
                                     .build(),
                                 tx.clone())?;
                std::thread::sleep(Duration::from_secs_f64(
                    rng.exponential(rate)));
            }
            Ok(truth_fill)
        })
    };

    let mut hist = Histogram::new();
    let mut preds = vec![0usize; n_requests];
    for _ in 0..n_requests {
        let resp = rx.recv()?;
        hist.record(resp.latency.as_secs_f64());
        preds[resp.id as usize] =
            argmax_rows(resp.logits.f32s()?, resp.logits.shape[0])[0];
    }
    let wall = t0.elapsed().as_secs_f64();
    let truth_filled = feeder.join().expect("feeder panicked")?;
    truth.copy_from_slice(&truth_filled);
    server.shutdown()?;

    let correct =
        preds.iter().zip(&truth).filter(|(a, b)| a == b).count();
    println!("  throughput : {:.1} req/s ({} requests in {:.2} s)",
             n_requests as f64 / wall, n_requests, wall);
    println!("  latency    : {}", hist.summary_ms());
    println!("  accuracy   : {:.2}% online", 100.0 * correct as f64
             / n_requests as f64);
    Ok(())
}
