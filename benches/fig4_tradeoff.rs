//! Reproduces paper Fig. 4: accuracy (lines) and communication speed-up
//! (bars) vs compression rate, for ViT on the three vision datasets with
//! P = 2 and P = 3. Prints the (CR, comm-speed-up, accuracy) series that
//! the figure plots.

use anyhow::Result;

use prism::bench_util::{eval_limit, require_artifacts};
use prism::coordinator::plan::effective_cr;
use prism::coordinator::{Mode, Runner};
use prism::data::Dataset;
use prism::eval::{evaluate, EvalOpts};
use prism::metrics::report::{f2, pct, Table};
use prism::model::comm;
use prism::runtime::WeightSet;

fn main() -> Result<()> {
    let Some(m) = require_artifacts() else { return Ok(()) };
    let limit = eval_limit(256);
    let n = m.model("vit")?.n;
    let mut runner = Runner::new(m.clone(), "xla")?;

    for ds_name in ["synth10", "synth100", "synthhard"] {
        let ds = Dataset::load(&m.root, ds_name)?;
        let ws = WeightSet::load(&m, &format!("vit_{ds_name}"))?;
        let mut table = Table::new(
            &format!("Fig. 4 — accuracy / comm-speed-up vs CR ({ds_name})"),
            &["P", "L", "CR", "CommSU%", "Accuracy%"],
        );
        let single = evaluate(&mut runner, &ws, &ds,
                              &EvalOpts { mode: Mode::Single, limit })?;
        table.row(vec!["1".into(), "-".into(), "-".into(), "-".into(),
                       pct(single.metric)]);
        for (p, ls) in [(2usize, vec![3usize, 6, 10]), (3, vec![3, 5, 10])]
        {
            for l in ls {
                let mode = Mode::Prism { p, l, duplicated: true };
                let res = evaluate(&mut runner, &ws, &ds,
                                   &EvalOpts { mode, limit })?;
                table.row(vec![
                    p.to_string(),
                    l.to_string(),
                    f2(effective_cr(n, p, l)),
                    pct(comm::comm_speedup(n, p, l)),
                    pct(res.metric),
                ]);
                eprintln!("  [{ds_name} p={p} l={l}] acc {:.4}",
                          res.metric);
            }
        }
        table.print();
        println!();
    }
    println!("paper reference (Fig. 4): accuracy falls monotonically as \
              CR rises; the drop is steeper for the harder datasets and \
              slightly worse for P=3 than P=2 at equal CR.");
    Ok(())
}
