//! §Perf tenants bench: classful multi-tenant serving vs the
//! class-blind FIFO baseline on the same overloaded Zipf-skewed fleet
//! (`SoakCfg::tenants` — 16k mixed streams from 40 tenants at ~30%
//! over decode capacity, kill/revive churn), reporting per-class
//! virtual latency percentiles, shed counts, and the Interactive p99
//! win priority buys.
//!
//! Artifact-free (the sim's stand-in blocks need no AOT artifacts), so
//! this runs on any checkout:
//!
//!     cargo bench --bench tenants_soak

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;
use prism::sim::{run_soak, SoakCfg};
use prism::tenant::RequestClass;
use prism::util::json::Json;

fn main() -> Result<()> {
    let cfg = SoakCfg::tenants(11);
    let ten = cfg.tenancy.as_ref().unwrap();
    println!("== tenants soak (virtual clock, {} tenants, {} offered \
              streams, caps {:?}, churn) ==",
             ten.cfg.tenants, cfg.workload.requests, ten.cfg.shed_caps);

    let t0 = Instant::now();
    let prio = run_soak(&cfg)?;
    let base = run_soak(&SoakCfg::tenants_unprioritized(11))?;
    let wall = t0.elapsed().as_secs_f64();

    // contract: the gate sheds (the preset is overloaded), nothing
    // admitted is ever lost, and priority buys the Interactive tail
    assert_eq!(prio.dropped(), 0, "classful run dropped admitted work");
    assert_eq!(base.dropped(), 0, "baseline run dropped admitted work");
    assert!(prio.tenancy.shed() > 0, "overloaded preset never shed");
    let p_p99 = prio.tenancy.class(RequestClass::Interactive)
        .latency.p99();
    let b_p99 = base.tenancy.class(RequestClass::Interactive)
        .latency.p99();
    let speedup = b_p99 / p_p99.max(1e-9);
    assert!(speedup > 1.0, "classful p99 {p_p99:.3}s not below FIFO \
                            baseline {b_p99:.3}s");
    assert!(wall < 120.0, "tenants bench too slow: {wall:.1}s wall");

    let mut obj: BTreeMap<String, Json> = BTreeMap::new();
    obj.insert("bench".into(), Json::Str("tenants_soak".into()));
    obj.insert("seed".into(), Json::Num(cfg.seed as f64));
    obj.insert("offered".into(), Json::Num(prio.offered() as f64));
    obj.insert("admitted".into(),
               Json::Num(prio.tenancy.admitted() as f64));
    obj.insert("shed".into(), Json::Num(prio.tenancy.shed() as f64));
    for class in RequestClass::ALL {
        let c = prio.tenancy.class(class);
        let name = class.name();
        println!("{name:12}: admitted {:6} shed {:6} (quota {:5}) \
                  p50 {:8.2}ms p99 {:8.2}ms",
                 c.admitted, c.shed(), c.shed_quota,
                 c.latency.p50() * 1e3, c.latency.p99() * 1e3);
        obj.insert(format!("{name}_admitted"),
                   Json::Num(c.admitted as f64));
        obj.insert(format!("{name}_shed"), Json::Num(c.shed() as f64));
        obj.insert(format!("{name}_p50_ms"),
                   Json::Num(c.latency.p50() * 1e3));
        obj.insert(format!("{name}_p99_ms"),
                   Json::Num(c.latency.p99() * 1e3));
    }
    println!("fifo base   : interactive p99 {:.2}ms", b_p99 * 1e3);
    println!("p99 win     : {speedup:.2}x (classful vs class-blind)");
    println!("wall        : {wall:.2}s to simulate both runs");
    obj.insert("baseline_interactive_p99_ms".into(),
               Json::Num(b_p99 * 1e3));
    obj.insert("interactive_p99_speedup".into(), Json::Num(speedup));
    obj.insert("wall_secs".into(), Json::Num(wall));
    let path = "BENCH_tenants.json";
    std::fs::write(path, Json::Obj(obj).dump())?;
    println!("json        : {path}");
    Ok(())
}
