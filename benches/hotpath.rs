//! §Perf micro-benchmarks for the request hot path, per layer:
//!
//!   L3  — plan/bias construction, Segment Means (rust), tensor
//!         slice/concat, message codec, batcher-side row stacking,
//!         end-to-end block dispatch overhead (engine.run minus XLA time)
//!   L2  — AOT block executables (xla flavor): per-block latency across
//!         strategies/batch sizes
//!   L1  — pallas-flavor block vs xla-flavor block (interpret-mode cost
//!         on CPU; on TPU the pallas kernel is the optimized path)
//!
//! Results feed EXPERIMENTS.md §Perf.

use anyhow::Result;

use prism::bench_util::{bench, require_artifacts};
use prism::coordinator::plan::plans;
use prism::coordinator::segmeans::segment_means;
use prism::net::message::Msg;
use prism::runtime::{Engine, Tensor, WeightSet};
use prism::util::rng::Rng;

fn main() -> Result<()> {
    let Some(m) = require_artifacts() else { return Ok(()) };
    let mut rng = Rng::new(1);

    println!("== L3 substrate micro-benches ==");
    {
        let st = bench(10, 200, || {
            let pls = plans(65, 3, 5, true).unwrap();
            for pl in &pls {
                std::hint::black_box(pl.bias().unwrap());
            }
        });
        println!("plan+bias build (N=65,P=3,L=5,causal): {}", st.per_op());

        let x = Tensor::from_f32(vec![16, 33, 128],
                                 rng.normal_vec(16 * 33 * 128, 1.0))?;
        let st = bench(10, 200, || {
            std::hint::black_box(segment_means(&x, 6).unwrap());
        });
        println!("segment_means (16x33x128 -> L=6):      {}", st.per_op());

        let st = bench(10, 200, || {
            let a = x.slice1(0, 16).unwrap();
            let b = x.slice1(16, 33).unwrap();
            std::hint::black_box(Tensor::concat1(&[&a, &b]).unwrap());
        });
        println!("slice1 + concat1 (16x33x128):          {}", st.per_op());

        let z = Tensor::from_f32(vec![16, 6, 128],
                                 rng.normal_vec(16 * 6 * 128, 1.0))?;
        let msg = Msg::Exchange { epoch: 0, layer: 0, from: 0, data: z };
        let st = bench(10, 500, || {
            let buf = msg.encode();
            std::hint::black_box(Msg::decode(&buf).unwrap());
        });
        println!("exchange codec roundtrip (48 KiB):     {}", st.per_op());
    }

    println!("\n== L2 block executables (xla flavor, steady state) ==");
    let mut engine = Engine::new(m.clone())?;
    let ws = WeightSet::load(&m, "vit_synth10")?;
    let gws = WeightSet::load(&m, "gpt2")?;
    let cases = [
        ("vit_single_part0_b16_xla", "vit single   b16", &ws),
        ("vit_voltage_p2_part0_b16_xla", "vit voltage  b16", &ws),
        ("vit_prism_p2l6_part0_b16_xla", "vit prism    b16", &ws),
        ("vit_prism_p2l6_part0_b1_xla", "vit prism    b1 ", &ws),
        ("gpt2_prism_p2l16_part0_b16_xla", "gpt2 prism   b16", &gws),
    ];
    for (exec, label, wsx) in cases {
        let spec = m.exec(exec)?.clone();
        let args: Vec<Tensor> = spec
            .args
            .iter()
            .map(|a| {
                let numel: usize = a.shape.iter().product();
                Tensor::from_f32(a.shape.clone(),
                                 rng.normal_vec(numel, 0.3)).unwrap()
            })
            .collect();
        let refs: Vec<&Tensor> = args.iter().collect();
        engine.ensure_compiled(exec)?;
        let st = bench(3, 30, || {
            std::hint::black_box(
                engine.run(exec, wsx, 1, &refs).unwrap());
        });
        println!("{label}: {}", st.per_op());
    }

    println!("\n== L1 pallas (interpret) vs xla fused flavor ==");
    for flavor in ["xla", "pallas"] {
        let exec = format!("vit_prism_p2l6_part0_b16_{flavor}");
        let spec = m.exec(&exec)?.clone();
        let args: Vec<Tensor> = spec
            .args
            .iter()
            .map(|a| {
                let numel: usize = a.shape.iter().product();
                Tensor::from_f32(a.shape.clone(),
                                 rng.normal_vec(numel, 0.3)).unwrap()
            })
            .collect();
        let refs: Vec<&Tensor> = args.iter().collect();
        engine.ensure_compiled(&exec)?;
        let st = bench(3, 20, || {
            std::hint::black_box(engine.run(&exec, &ws, 1, &refs).unwrap());
        });
        println!("vit prism block b16 [{flavor:>6}]: {}", st.per_op());
    }
    println!("\n(engine stats: {} compiles, {:.0} ms compiling, {} \
              executions)", engine.stats.compiles,
             engine.stats.compile_ms, engine.stats.executions);
    Ok(())
}
