//! §Perf micro-benchmarks for the request hot path, per layer:
//!
//!   L3  — plan/bias construction, Segment Means (rust), tensor
//!         slice/concat, message codec, row quantization, decode wire
//!         bytes per token. Artifact-free: this section runs on any
//!         checkout and writes `BENCH_hotpath.json`, the record
//!         `scripts/bench_gate` ratchets against `bench_baseline.json`.
//!   L2  — AOT block executables (xla flavor): per-block latency across
//!         strategies/batch sizes (needs `make artifacts`)
//!   L1  — pallas-flavor block vs xla-flavor block (interpret-mode cost
//!         on CPU; on TPU the pallas kernel is the optimized path)
//!
//! The ratcheted metrics are *ratios* (old in-tree oracle vs new kernel,
//! timed back-to-back in the same process) plus deterministic byte
//! counts, so the gate is machine-independent: absolute nanoseconds are
//! recorded for trend plots but never gated.
//!
//! Results feed EXPERIMENTS.md §Perf.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use prism::bench_util::{bench, require_artifacts};
use prism::coordinator::plan::plans;
use prism::coordinator::segmeans::{segment_means, segment_means_reference};
use prism::decode::{DecodeSession, RefCfg, RefGpt};
use prism::net::message::Msg;
use prism::runtime::{Engine, Tensor, TensorData, WeightSet};
use prism::util::json::Json;
use prism::util::quant::{self, WireFmt};
use prism::util::rng::Rng;

/// The pre-zero-copy Exchange encoder — a fresh allocation per frame
/// and a bounds-checked `extend` per element — kept as the ratchet's
/// speedup denominator. Byte-identity against `Msg::encode_into` is
/// asserted once below before any timing.
fn encode_exchange_reference(msg: &Msg) -> Vec<u8> {
    let Msg::Exchange { epoch, layer, from, data } = msg else {
        panic!("reference encoder only covers Msg::Exchange");
    };
    let mut out = Vec::new();
    out.push(0u8);
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&layer.to_le_bytes());
    out.extend_from_slice(&from.to_le_bytes());
    out.push(match data.data {
        TensorData::F32(_) => 0u8,
        TensorData::I32(_) => 1u8,
    });
    out.push(data.shape.len() as u8);
    for &d in &data.shape {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    match &data.data {
        TensorData::F32(v) => {
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        TensorData::I32(v) => {
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    out
}

fn main() -> Result<()> {
    let mut rng = Rng::new(1);
    let mut obj: BTreeMap<String, Json> = BTreeMap::new();
    obj.insert("bench".into(), Json::Str("hotpath".into()));

    println!("== L3 substrate micro-benches (artifact-free) ==");

    let st = bench(10, 200, || {
        let pls = plans(65, 3, 5, true).unwrap();
        for pl in &pls {
            std::hint::black_box(pl.bias().unwrap());
        }
    });
    println!("plan+bias build (N=65,P=3,L=5,causal): {}", st.per_op());
    obj.insert("plan_bias_ns".into(), Json::Num(st.median_secs * 1e9));

    // -- segment means: sequential oracle vs chunked kernel ------------
    let x = Tensor::from_f32(vec![16, 33, 128],
                             rng.normal_vec(16 * 33 * 128, 1.0))?;
    assert_eq!(segment_means_reference(&x, 6)?.f32s()?,
               segment_means(&x, 6)?.f32s()?,
               "chunked segment_means diverged from the oracle");
    let ref_st = bench(10, 200, || {
        std::hint::black_box(segment_means_reference(&x, 6).unwrap());
    });
    let new_st = bench(10, 200, || {
        std::hint::black_box(segment_means(&x, 6).unwrap());
    });
    let sm_speedup = ref_st.median_secs / new_st.median_secs;
    println!("segment_means (16x33x128 -> L=6):      ref {} | chunked {} \
              | {sm_speedup:.2}x", ref_st.per_op(), new_st.per_op());
    obj.insert("segment_means_ref_ns".into(),
               Json::Num(ref_st.median_secs * 1e9));
    obj.insert("segment_means_ns".into(),
               Json::Num(new_st.median_secs * 1e9));
    obj.insert("segment_means_speedup".into(), Json::Num(sm_speedup));

    let st = bench(10, 200, || {
        let a = x.slice1(0, 16).unwrap();
        let b = x.slice1(16, 33).unwrap();
        std::hint::black_box(Tensor::concat1(&[&a, &b]).unwrap());
    });
    println!("slice1 + concat1 (16x33x128):          {}", st.per_op());
    obj.insert("slice_concat_ns".into(), Json::Num(st.median_secs * 1e9));

    // -- exchange codec roundtrip: per-element alloc vs zero-copy ------
    let z = Tensor::from_f32(vec![16, 6, 128],
                             rng.normal_vec(16 * 6 * 128, 1.0))?;
    let msg = Msg::Exchange { epoch: 0, layer: 0, from: 0, data: z };
    let mut frame = Vec::new();
    msg.encode_into(&mut frame);
    assert_eq!(frame, encode_exchange_reference(&msg),
               "encode_into diverged from the reference frame bytes");
    let ref_st = bench(10, 500, || {
        let buf = encode_exchange_reference(&msg);
        std::hint::black_box(Msg::decode(&buf).unwrap());
    });
    let mut buf = Vec::new();
    let new_st = bench(10, 500, || {
        msg.encode_into(&mut buf);
        std::hint::black_box(Msg::decode(&buf).unwrap());
    });
    let codec_speedup = ref_st.median_secs / new_st.median_secs;
    println!("exchange codec roundtrip (48 KiB):     ref {} | zero-copy \
              {} | {codec_speedup:.2}x", ref_st.per_op(), new_st.per_op());
    obj.insert("codec_roundtrip_ref_ns".into(),
               Json::Num(ref_st.median_secs * 1e9));
    obj.insert("codec_roundtrip_ns".into(),
               Json::Num(new_st.median_secs * 1e9));
    obj.insert("codec_roundtrip_speedup".into(), Json::Num(codec_speedup));

    // -- i8 row quantization: sequential oracle vs chunked absmax ------
    let q = Tensor::from_f32(vec![64, 256], rng.normal_vec(64 * 256, 1.0))?;
    assert_eq!(quant::encode_reference(&q, WireFmt::I8)?,
               quant::encode(&q, WireFmt::I8)?,
               "chunked i8 quant diverged from the oracle");
    let ref_st = bench(10, 300, || {
        std::hint::black_box(
            quant::encode_reference(&q, WireFmt::I8).unwrap());
    });
    let mut qbuf = Vec::new();
    let new_st = bench(10, 300, || {
        quant::encode_into(&q, WireFmt::I8, &mut qbuf).unwrap();
        std::hint::black_box(&qbuf);
    });
    let quant_speedup = ref_st.median_secs / new_st.median_secs;
    println!("i8 row quant (64x256):                 ref {} | chunked {} \
              | {quant_speedup:.2}x", ref_st.per_op(), new_st.per_op());
    obj.insert("i8_quant_ref_ns".into(),
               Json::Num(ref_st.median_secs * 1e9));
    obj.insert("i8_quant_ns".into(), Json::Num(new_st.median_secs * 1e9));
    obj.insert("i8_quant_speedup".into(), Json::Num(quant_speedup));

    // -- decode wire bytes per absorbed token (deterministic) ----------
    // P=2, layers=4, d=64, f32: 1024 coalesced delta bytes + 4 sync
    // bytes per token = exactly 1028.0, gated at zero tolerance so any
    // accidental framing growth fails CI.
    let cfg = RefCfg { vocab: 56, n: 128, d: 64, heads: 4, layers: 4,
                       ffn: 128 };
    let model = Arc::new(RefGpt::tiny(31, cfg)?);
    let mut sess = DecodeSession::new(model, 2, 4, WireFmt::F32)?;
    let prompt: Vec<i32> = (0..8).map(|i| (i % 50) + 1).collect();
    sess.prefill(&prompt)?;
    for _ in 0..24 {
        sess.generate_next()?;
    }
    let bpt = sess.stats().bytes_per_token();
    println!("decode wire bytes/token (P=2,L=4,f32): {bpt:.1}");
    obj.insert("bytes_per_token".into(), Json::Num(bpt));

    // machine-readable record for the CI perf ratchet; written before
    // the artifact gate so `scripts/bench_gate` works on any checkout.
    let path = "BENCH_hotpath.json";
    std::fs::write(path, Json::Obj(obj).dump())?;
    println!("json: {path}");

    let Some(m) = require_artifacts() else { return Ok(()) };

    println!("\n== L2 block executables (xla flavor, steady state) ==");
    let mut engine = Engine::new(m.clone())?;
    let ws = WeightSet::load(&m, "vit_synth10")?;
    let gws = WeightSet::load(&m, "gpt2")?;
    let cases = [
        ("vit_single_part0_b16_xla", "vit single   b16", &ws),
        ("vit_voltage_p2_part0_b16_xla", "vit voltage  b16", &ws),
        ("vit_prism_p2l6_part0_b16_xla", "vit prism    b16", &ws),
        ("vit_prism_p2l6_part0_b1_xla", "vit prism    b1 ", &ws),
        ("gpt2_prism_p2l16_part0_b16_xla", "gpt2 prism   b16", &gws),
    ];
    for (exec, label, wsx) in cases {
        let spec = m.exec(exec)?.clone();
        let args: Vec<Tensor> = spec
            .args
            .iter()
            .map(|a| {
                let numel: usize = a.shape.iter().product();
                Tensor::from_f32(a.shape.clone(),
                                 rng.normal_vec(numel, 0.3)).unwrap()
            })
            .collect();
        let refs: Vec<&Tensor> = args.iter().collect();
        engine.ensure_compiled(exec)?;
        let st = bench(3, 30, || {
            std::hint::black_box(
                engine.run(exec, wsx, 1, &refs).unwrap());
        });
        println!("{label}: {}", st.per_op());
    }

    println!("\n== L1 pallas (interpret) vs xla fused flavor ==");
    for flavor in ["xla", "pallas"] {
        let exec = format!("vit_prism_p2l6_part0_b16_{flavor}");
        let spec = m.exec(&exec)?.clone();
        let args: Vec<Tensor> = spec
            .args
            .iter()
            .map(|a| {
                let numel: usize = a.shape.iter().product();
                Tensor::from_f32(a.shape.clone(),
                                 rng.normal_vec(numel, 0.3)).unwrap()
            })
            .collect();
        let refs: Vec<&Tensor> = args.iter().collect();
        engine.ensure_compiled(&exec)?;
        let st = bench(3, 20, || {
            std::hint::black_box(engine.run(&exec, &ws, 1, &refs).unwrap());
        });
        println!("vit prism block b16 [{flavor:>6}]: {}", st.per_op());
    }
    println!("\n(engine stats: {} compiles, {:.0} ms compiling, {} \
              executions)", engine.stats.compiles,
             engine.stats.compile_ms, engine.stats.executions);
    Ok(())
}
