//! §Perf linkplan bench: direct (link-blind) vs bandwidth-aware relayed
//! exchange planning on the same seeded degraded mesh
//! (`SoakCfg::linkplan` — an equal-speed fleet with one directed edge
//! delay-ramped mid-run), reporting both runs' virtual latency
//! percentiles, the bytes each pushed over the degraded edge, and the
//! wall cost.
//!
//! Artifact-free (the sim's stand-in blocks need no AOT artifacts), so
//! this runs on any checkout:
//!
//!     cargo bench --bench linkplan_soak

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;
use prism::sim::{run_soak, SoakCfg};
use prism::util::json::Json;

fn main() -> Result<()> {
    let cfg = SoakCfg::linkplan(11);
    println!("== linkplan soak (virtual clock, P={} L={}, {} mixed \
              requests, mid-run delay ramp on edge 0 -> 1) ==",
             cfg.p, cfg.l, cfg.workload.requests);

    let t0 = Instant::now();
    let relayed = run_soak(&cfg)?;
    let mut direct_cfg = cfg.clone();
    direct_cfg.link_factor = None;
    direct_cfg.replan_deadband = None;
    let direct = run_soak(&direct_cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    // contract: both runs are drop-free; only the link-aware one
    // re-plans, its relay starves the degraded edge, and it wins on
    // tail latency
    assert_eq!(relayed.dropped(), 0, "relayed run dropped requests");
    assert_eq!(direct.dropped(), 0, "direct run dropped requests");
    assert!(!relayed.relay_plans.is_empty(), "no relay table shipped");
    assert!(direct.replans.is_empty(), "direct run re-planned");
    assert!(wall < 60.0, "linkplan bench too slow: {wall:.1}s wall");

    let r_edge = relayed.edge_bytes[0][1];
    let d_edge = direct.edge_bytes[0][1];
    let r_p50 = relayed.eval_latency.p50() * 1e3;
    let r_p99 = relayed.eval_latency.p99() * 1e3;
    let d_p50 = direct.eval_latency.p50() * 1e3;
    let d_p99 = direct.eval_latency.p99() * 1e3;
    println!("direct   : eval p50 {d_p50:.2}ms p99 {d_p99:.2}ms, \
              {d_edge} B over the degraded edge \
              ({:.2}s virtual)", direct.virtual_secs);
    println!("relayed  : eval p50 {r_p50:.2}ms p99 {r_p99:.2}ms, \
              {r_edge} B over the degraded edge \
              ({:.2}s virtual, {} re-plans, route {:?})",
             relayed.virtual_secs, relayed.replans.len(),
             relayed.relay_plans[0].1);
    println!("p99 win  : {:.2}x", d_p99 / r_p99.max(1e-9));
    println!("edge win : {:.2}x fewer bytes on the degraded edge",
             d_edge as f64 / (r_edge as f64).max(1.0));
    println!("wall     : {wall:.2}s to simulate both runs");

    // machine-readable record for the CI perf-trajectory artifact
    // (uploaded as BENCH_*.json per PR)
    let mut obj: BTreeMap<String, Json> = BTreeMap::new();
    obj.insert("bench".into(), Json::Str("linkplan_soak".into()));
    obj.insert("seed".into(), Json::Num(cfg.seed as f64));
    obj.insert("requests".into(),
               Json::Num(relayed.requests() as f64));
    obj.insert("direct_eval_p50_ms".into(), Json::Num(d_p50));
    obj.insert("direct_eval_p99_ms".into(), Json::Num(d_p99));
    obj.insert("relayed_eval_p50_ms".into(), Json::Num(r_p50));
    obj.insert("relayed_eval_p99_ms".into(), Json::Num(r_p99));
    obj.insert("p99_speedup".into(),
               Json::Num(d_p99 / r_p99.max(1e-9)));
    obj.insert("direct_edge_bytes".into(), Json::Num(d_edge as f64));
    obj.insert("relayed_edge_bytes".into(), Json::Num(r_edge as f64));
    obj.insert("replans".into(),
               Json::Num(relayed.replans.len() as f64));
    obj.insert("relayed_virtual_secs".into(),
               Json::Num(relayed.virtual_secs));
    obj.insert("direct_virtual_secs".into(),
               Json::Num(direct.virtual_secs));
    obj.insert("wall_secs".into(), Json::Num(wall));
    let path = "BENCH_linkplan.json";
    std::fs::write(path, Json::Obj(obj).dump())?;
    println!("json     : {path}");
    Ok(())
}
