//! Reproduces paper Fig. 5: single-query (batch = 1) ViT inference latency
//! vs network bandwidth (50–1000 Mbps).
//!
//! Two tables:
//!
//! 1. **Paper scale** (the headline reproduction): ViT-Base FLOPs at a
//!    host throughput *calibrated from this machine's measured PJRT
//!    executions*, analytical exchange bytes, shared-wireless-medium
//!    composition — the regime the paper actually evaluates (seconds of
//!    compute).
//! 2. **Tiny measured**: the real AOT artifacts end-to-end. At ~10 ms of
//!    compute, link latency dominates and no distribution wins — reported
//!    for honesty about the executable scale.

use anyhow::Result;

use prism::bench_util::require_artifacts;
use prism::coordinator::{Mode, RunTrace, Runner};
use prism::data::Dataset;
use prism::metrics::report::Table;
use prism::model::paper::{dims_from_cfg, VIT_BASE};
use prism::model::predict::{calibrate_gflops, paper_trace};
use prism::net::LinkModel;
use prism::runtime::WeightSet;

const BANDWIDTHS: [f64; 5] = [50.0, 100.0, 200.0, 500.0, 1000.0];
const LINK_LATENCY_MS: f64 = 2.0;

/// Paper-scale points (ViT-Base, N=197): the exact Fig. 5 strategies.
fn paper_strategies() -> Vec<(String, Mode)> {
    vec![
        ("single".into(), Mode::Single),
        ("voltage p=2".into(), Mode::Voltage { p: 2 }),
        ("voltage p=3".into(), Mode::Voltage { p: 3 }),
        // CR=9.9 (P=2, L=10) and CR=6.55 (P=3, L=10), plus a low-CR point
        ("prism p=2 CR=9.9".into(),
         Mode::Prism { p: 2, l: 10, duplicated: true }),
        ("prism p=3 CR=6.6".into(),
         Mode::Prism { p: 3, l: 10, duplicated: true }),
        ("prism p=2 CR=3.3".into(),
         Mode::Prism { p: 2, l: 30, duplicated: true }),
    ]
}

/// Tiny-artifact points (must exist in the manifest: L in {3, 6, 10}).
fn tiny_strategies() -> Vec<(String, Mode)> {
    vec![
        ("single".into(), Mode::Single),
        ("voltage p=2".into(), Mode::Voltage { p: 2 }),
        ("voltage p=3".into(), Mode::Voltage { p: 3 }),
        ("prism p=2 CR=10.8".into(),
         Mode::Prism { p: 2, l: 3, duplicated: true }),
        ("prism p=3 CR=7.2".into(),
         Mode::Prism { p: 3, l: 3, duplicated: true }),
        ("prism p=2 CR=3.2".into(),
         Mode::Prism { p: 2, l: 10, duplicated: true }),
    ]
}

fn render(title: &str, rows: Vec<(String, RunTrace)>, unit_ms: bool) {
    let mut headers: Vec<String> =
        vec!["strategy".into(), "compute".into()];
    headers.extend(BANDWIDTHS.iter().map(|b| format!("{b:.0}Mbps")));
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(title, &hrefs);
    let single_latency: Vec<f64> = rows
        .first()
        .map(|(_, t)| {
            BANDWIDTHS
                .iter()
                .map(|bw| {
                    let mut l = LinkModel::new(*bw, LINK_LATENCY_MS);
                    l.shared_medium = true;
                    t.latency_secs(l)
                })
                .collect()
        })
        .unwrap_or_default();
    for (label, trace) in &rows {
        let scale = if unit_ms { 1e3 } else { 1.0 };
        let suffix = if unit_ms { "ms" } else { "s" };
        let mut cells =
            vec![label.clone(),
                 format!("{:.2}{suffix}",
                         trace.total_compute_secs() * scale)];
        for (i, bw) in BANDWIDTHS.iter().enumerate() {
            let mut link = LinkModel::new(*bw, LINK_LATENCY_MS);
            link.shared_medium = true;
            let v = trace.latency_secs(link) * scale;
            let mark = if label != "single" && v / scale
                >= single_latency[i]
            {
                "*"
            } else {
                ""
            };
            cells.push(format!("{v:.2}{mark}"));
        }
        table.row(cells);
    }
    table.print();
    println!("(* = not faster than single-device at that bandwidth)\n");
}

fn main() -> Result<()> {
    let Some(m) = require_artifacts() else { return Ok(()) };
    let mut runner = Runner::new(m.clone(), "xla")?;
    let ws = WeightSet::load(&m, "vit_synth10")?;
    let ds = Dataset::load(&m.root, "synth10")?;
    let cfg = m.model("vit")?.clone();
    let tiny_dims = dims_from_cfg(&cfg);

    // measure tiny traces (best of 5, batch 1)
    let raw = ds.x.slice0(0, m.latency_batch)?;
    let mut tiny_rows = Vec::new();
    let mut calib = None;
    for (label, mode) in tiny_strategies() {
        let mut best: Option<RunTrace> = None;
        for _ in 0..5 {
            let (_, t) = runner.forward("vit", &ws, "synth10", &raw,
                                        mode)?;
            if best
                .as_ref()
                .map(|b| t.total_compute_secs() < b.total_compute_secs())
                .unwrap_or(true)
            {
                best = Some(t);
            }
        }
        let trace = best.unwrap();
        if matches!(mode, Mode::Single) {
            calib = Some(calibrate_gflops(&tiny_dims, m.latency_batch,
                                          mode, &trace));
        }
        tiny_rows.push((label, trace));
    }
    let host_gflops = calib.unwrap();
    println!("calibrated host throughput: {host_gflops:.2} GFLOPS \
              (measured on the batch-1 single-device artifacts)\n");

    // paper-scale prediction
    let paper_rows: Vec<(String, RunTrace)> = paper_strategies()
        .into_iter()
        .map(|(label, mode)| {
            (label, paper_trace(&VIT_BASE, mode, host_gflops))
        })
        .collect();
    render("Fig. 5 — ViT-Base single-query latency (s) vs bandwidth \
            (paper scale; compute calibrated, transfers modeled, shared \
            medium)", paper_rows, false);

    render("Fig. 5 (auxiliary) — tiny executable models, measured compute \
            (ms): at this scale link latency dominates and distribution \
            cannot win", tiny_rows, true);

    println!("paper reference (Fig. 5): at 200 Mbps PRISM cuts latency \
              43.3% (P=2, CR=9.9) / 52.6% (P=3, CR=6.55) vs single \
              device; Voltage is slower than single at low bandwidth; \
              margins shrink as bandwidth grows.");
    Ok(())
}
