//! Reproduces paper Table V: BERT over the 8 GLUE-proxy tasks.
//!
//! GFLOPs at paper scale (BERT-Base, N = 256); task metrics measured
//! end-to-end on the AOT artifacts (Acc / F1 / MCC / Spearman, matching
//! the paper's per-task metric choices).

use anyhow::Result;

use prism::bench_util::{eval_limit, require_artifacts};
use prism::coordinator::plan::{effective_cr, landmarks_for_cr};
use prism::coordinator::{Mode, Runner};
use prism::data::Dataset;
use prism::eval::{evaluate, EvalOpts};
use prism::metrics::report::{f2, opt, pct, Table};
use prism::model::paper::BERT_BASE;
use prism::model::{comm, flops};
use prism::runtime::WeightSet;

const TASKS: [&str; 8] =
    ["stsbp", "sst2p", "rtep", "qqpp", "qnlip", "mrpcp", "colap", "mnlip"];

fn main() -> Result<()> {
    let Some(m) = require_artifacts() else { return Ok(()) };
    let limit = eval_limit(256);
    let n = m.model("bert")?.n;
    let ws = WeightSet::load(&m, "bert")?;
    let mut runner = Runner::new(m.clone(), "xla")?;
    let datasets: Vec<Dataset> = TASKS
        .iter()
        .map(|t| Dataset::load(&m.root, t))
        .collect::<Result<_>>()?;

    let rows: Vec<(&str, Mode)> = vec![
        ("No partition", Mode::Single),
        ("Voltage", Mode::Voltage { p: 2 }),
        ("Voltage", Mode::Voltage { p: 3 }),
        ("PRISM", Mode::Prism { p: 2, l: 3, duplicated: true }),
        ("PRISM", Mode::Prism { p: 2, l: 1, duplicated: true }),
        ("PRISM", Mode::Prism { p: 3, l: 2, duplicated: true }),
        ("PRISM", Mode::Prism { p: 3, l: 1, duplicated: true }),
    ];

    let mut headers = vec!["Strategy", "P", "GFLOPs", "GFLOPs/dev",
                           "CompSU%", "CR", "CommSU%"];
    headers.extend(TASKS);
    let mut table = Table::new(
        "Table V — BERT computation & communication efficiency \
         (GFLOPs at paper scale; metrics measured)",
        &headers,
    );
    let single = flops::single_total(&BERT_BASE);
    for (label, mode) in rows {
        let p = mode.p();
        let (total, per_dev, cr, comm_su) = match mode {
            Mode::Single => (single, single, None, None),
            Mode::Voltage { p } => {
                let t = flops::voltage_total(&BERT_BASE, p);
                (t, t / p as f64, None, None)
            }
            Mode::Prism { p, l, .. } => {
                let cr = effective_cr(n, p, l);
                let lp = landmarks_for_cr(BERT_BASE.n, p, cr);
                let t = flops::prism_total(&BERT_BASE, p, lp);
                (t, t / p as f64, Some(cr),
                 Some(comm::comm_speedup(BERT_BASE.n, p, lp)))
            }
        };
        let mut cells = vec![
            label.to_string(),
            p.to_string(),
            f2(total / 1e9),
            f2(per_dev / 1e9),
            if matches!(mode, Mode::Single) { "-".into() }
            else { pct(flops::comp_speedup(per_dev, single)) },
            opt(cr, f2),
            opt(comm_su, pct),
        ];
        for ds in &datasets {
            let res =
                evaluate(&mut runner, &ws, ds, &EvalOpts { mode, limit })?;
            eprintln!("  [{label} p={p}] {} ({}) -> {:.4} ({:.1}s)",
                      ds.name, res.metric_name, res.metric,
                      res.total_secs);
            cells.push(pct(res.metric));
        }
        table.row(cells);
    }
    table.print();
    println!("\npaper reference (Table V): encoder classification is \
              robust — at P=2 CR=128 comm drops 99.22% with scores \
              virtually unchanged; only RTE/MNLI dip slightly.");
    Ok(())
}
