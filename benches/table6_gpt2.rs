//! Reproduces paper Table VI: GPT-2 under compression rates CR = 2..10
//! with P in {2, 3}.
//!
//! GFLOPs at paper scale (GPT-2 small, N = 256, LM head counted);
//! CBT-CN / CBT-NE cloze accuracies and BPB / BPC measured end-to-end on
//! the AOT artifacts with the partition-aware causal mask (Eq. 17).
//!
//! `PRISM_EVAL_LIMIT` caps BPC windows & cloze groups (default 48).

use anyhow::Result;
use std::collections::BTreeMap;

use prism::bench_util::{eval_limit, require_artifacts};
use prism::coordinator::plan::landmarks_for_cr;
use prism::coordinator::{Mode, Runner};
use prism::data::Dataset;
use prism::eval::{evaluate, EvalOpts};
use prism::metrics::report::{f2, opt, pct, Table};
use prism::model::paper::GPT2_SMALL;
use prism::model::{comm, flops};
use prism::runtime::WeightSet;

fn main() -> Result<()> {
    let Some(m) = require_artifacts() else { return Ok(()) };
    // cap: 21 distinct geometries x 4 metrics dominate bench
    // wallclock; 32 windows / cloze groups is enough for the trend.
    let limit = eval_limit(32).min(48);
    let n = m.model("gpt2")?.n;
    let ws = WeightSet::load(&m, "gpt2")?;
    let mut runner = Runner::new(m.clone(), "xla")?;
    let cbtcn = Dataset::load(&m.root, "cbtcn")?;
    let cbtne = Dataset::load(&m.root, "cbtne")?;
    let enwik = Dataset::load(&m.root, "enwik8p")?;
    let text8 = Dataset::load(&m.root, "text8p")?;

    let mut rows: Vec<(String, Mode, Option<usize>)> = vec![
        ("No partition".into(), Mode::Single, None),
        ("Voltage".into(), Mode::Voltage { p: 2 }, None),
        ("Voltage".into(), Mode::Voltage { p: 3 }, None),
    ];
    for p in [2usize, 3] {
        for cr in 2..=10usize {
            let l = landmarks_for_cr(n, p, cr as f64);
            rows.push((format!("PRISM"),
                       Mode::Prism { p, l, duplicated: true }, Some(cr)));
        }
    }

    let mut table = Table::new(
        "Table VI — GPT-2 computation & communication efficiency \
         (GFLOPs at paper scale; metrics measured)",
        &["Strategy", "P", "GFLOPs", "GFLOPs/dev", "CompSU%", "CR",
          "CommSU%", "CBT-CN", "CBT-NE", "BPB", "BPC"],
    );
    let single = flops::single_total(&GPT2_SMALL);
    // identical (p, l) pairs appear for several nominal CRs (Eq. 16 floor)
    // — evaluate each distinct geometry once.
    let mut cache: BTreeMap<(usize, usize, &'static str), (f64, f64, f64,
                                                           f64)> =
        BTreeMap::new();
    for (label, mode, nominal_cr) in rows {
        let p = mode.p();
        let key = (p, mode.l(), mode.name());
        let (cn, ne, bpb, bpc) = if let Some(v) = cache.get(&key) {
            *v
        } else {
            let cn = evaluate(&mut runner, &ws, &cbtcn,
                              &EvalOpts { mode, limit })?.metric;
            let ne = evaluate(&mut runner, &ws, &cbtne,
                              &EvalOpts { mode, limit })?.metric;
            let bpb = evaluate(&mut runner, &ws, &enwik,
                               &EvalOpts { mode, limit })?.metric;
            let bpc = evaluate(&mut runner, &ws, &text8,
                               &EvalOpts { mode, limit })?.metric;
            eprintln!("  [{label} p={p} l={}] cn {:.3} ne {:.3} bpb \
                       {:.3} bpc {:.3}", mode.l(), cn, ne, bpb, bpc);
            cache.insert(key, (cn, ne, bpb, bpc));
            (cn, ne, bpb, bpc)
        };
        let (total, per_dev, comm_su) = match mode {
            Mode::Single => (single, single, None),
            Mode::Voltage { p } => {
                let t = flops::voltage_total(&GPT2_SMALL, p);
                (t, t / p as f64, None)
            }
            Mode::Prism { p, .. } => {
                let cr = nominal_cr.unwrap() as f64;
                let lp = landmarks_for_cr(GPT2_SMALL.n, p, cr);
                let t = flops::prism_total(&GPT2_SMALL, p, lp);
                (t, t / p as f64,
                 Some(comm::comm_speedup(GPT2_SMALL.n, p, lp)))
            }
        };
        table.row(vec![
            label,
            p.to_string(),
            f2(total / 1e9),
            f2(per_dev / 1e9),
            if matches!(mode, Mode::Single) { "-".into() }
            else { pct(flops::comp_speedup(per_dev, single)) },
            nominal_cr.map(|c| c.to_string()).unwrap_or("-".into()),
            opt(comm_su, pct),
            pct(cn),
            pct(ne),
            f2(bpb),
            f2(bpc),
        ]);
    }
    table.print();
    println!("\npaper reference (Table VI): baseline CBT 79/80, BPB 1.34, \
              BPC 1.21; accuracy and BPC degrade smoothly as CR rises \
              (P=3 CR=10: 70/67, BPC 1.32); Voltage matches baseline.");
    Ok(())
}
