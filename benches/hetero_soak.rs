//! §Perf hetero bench: static equal split vs heterogeneity-aware
//! adaptive re-partitioning on the same seeded straggler fleet
//! (`SoakCfg::hetero` — modeled per-block compute time on the virtual
//! clock, one 4x-slow device, a mid-run thermal throttle), reporting
//! both runs' virtual latency percentiles and the wall cost.
//!
//! Artifact-free (the sim's stand-in blocks need no AOT artifacts), so
//! this runs on any checkout:
//!
//!     cargo bench --bench hetero_soak

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;
use prism::sim::{run_soak, SoakCfg};
use prism::util::json::Json;

fn main() -> Result<()> {
    let cfg = SoakCfg::hetero(11);
    println!("== hetero soak (virtual clock, P={} L={}, speeds {:?}, \
              {} mixed requests, mid-run throttle) ==",
             cfg.p, cfg.l, cfg.speeds, cfg.workload.requests);

    let t0 = Instant::now();
    let adaptive = run_soak(&cfg)?;
    let mut static_cfg = cfg.clone();
    static_cfg.replan_deadband = None;
    let fixed = run_soak(&static_cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    // contract: both runs are drop-free; only the adaptive one
    // re-plans, and it wins on tail latency
    assert_eq!(adaptive.dropped(), 0, "adaptive run dropped requests");
    assert_eq!(fixed.dropped(), 0, "static run dropped requests");
    assert!(!adaptive.replans.is_empty(), "no adaptive re-plan fired");
    assert!(fixed.replans.is_empty(), "static run re-planned");
    assert!(wall < 60.0, "hetero bench too slow: {wall:.1}s wall");

    let a_p50 = adaptive.eval_latency.p50() * 1e3;
    let a_p99 = adaptive.eval_latency.p99() * 1e3;
    let s_p50 = fixed.eval_latency.p50() * 1e3;
    let s_p99 = fixed.eval_latency.p99() * 1e3;
    println!("static   : eval p50 {s_p50:.2}ms p99 {s_p99:.2}ms \
              ({:.2}s virtual)", fixed.virtual_secs);
    println!("adaptive : eval p50 {a_p50:.2}ms p99 {a_p99:.2}ms \
              ({:.2}s virtual, {} re-plans)",
             adaptive.virtual_secs, adaptive.replans.len());
    println!("p99 win  : {:.2}x", s_p99 / a_p99.max(1e-9));
    println!("wall     : {wall:.2}s to simulate both runs");

    // machine-readable record for the CI perf-trajectory artifact
    // (uploaded as BENCH_*.json per PR)
    let mut obj: BTreeMap<String, Json> = BTreeMap::new();
    obj.insert("bench".into(), Json::Str("hetero_soak".into()));
    obj.insert("seed".into(), Json::Num(cfg.seed as f64));
    obj.insert("requests".into(),
               Json::Num(adaptive.requests() as f64));
    obj.insert("static_eval_p50_ms".into(), Json::Num(s_p50));
    obj.insert("static_eval_p99_ms".into(), Json::Num(s_p99));
    obj.insert("adaptive_eval_p50_ms".into(), Json::Num(a_p50));
    obj.insert("adaptive_eval_p99_ms".into(), Json::Num(a_p99));
    obj.insert("p99_speedup".into(),
               Json::Num(s_p99 / a_p99.max(1e-9)));
    obj.insert("replans".into(),
               Json::Num(adaptive.replans.len() as f64));
    obj.insert("adaptive_virtual_secs".into(),
               Json::Num(adaptive.virtual_secs));
    obj.insert("static_virtual_secs".into(),
               Json::Num(fixed.virtual_secs));
    obj.insert("wall_secs".into(), Json::Num(wall));
    let path = "BENCH_hetero.json";
    std::fs::write(path, Json::Obj(obj).dump())?;
    println!("json     : {path}");
    Ok(())
}
