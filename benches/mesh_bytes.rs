//! §Perf mesh bench: worker-to-worker mesh vs master-relay hub exchange
//! bytes (artifact-free, so this runs on any checkout).
//!
//! Runs the same Segment-Means-shaped all-to-all twice, both times over
//! real transports with every frame byte-accounted by `NetStats`:
//!
//! * **mesh** — `MeshTransport` with direct per-peer edges, each
//!   directed share crossing one link;
//! * **hub**  — the pre-mesh star: every worker's only edge is the
//!   master, which physically forwards each addressed share to its
//!   recipient (one copy per recipient, two link crossings per share).
//!
//! Contract: the *measured* mesh traffic is at most half the *measured*
//! hub traffic at every P — asserted against real counters, not the
//! analytical identity, so a regression that routes exchange frames
//! back through the master trips it. The analytical forms
//! (`mesh_exchange_bytes` / `hub_exchange_bytes`) are cross-checked
//! against both measurements.
//!
//!     cargo bench --bench mesh_bytes
//!
//! Writes BENCH_mesh_bytes.json for the CI perf-trajectory artifact.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{anyhow, Result};
use prism::net::mesh::{channel_edge, hub_exchange_bytes,
                       mesh_exchange_bytes, MeshTransport};
use prism::net::message::Msg;
use prism::net::{NetStats, Transport};
use prism::runtime::Tensor;
use prism::util::json::Json;

fn share_row(d: usize) -> Result<Tensor> {
    Tensor::from_f32(vec![d], vec![0.5; d])
}

fn recv_ms(node: &mut MeshTransport, ms: u64) -> Result<Msg> {
    node.recv_deadline(Duration::from_millis(ms))
        .map(|env| env.msg)
        .map_err(|e| anyhow!("recv: {e}"))
}

/// One L-round all-to-all over a direct P-node mesh; returns the
/// measured wire bytes.
fn run_mesh_exchange(p: usize, d: usize, layers: usize) -> Result<usize> {
    let stats = NetStats::new(p);
    let mut nodes: Vec<MeshTransport> = (0..p)
        .map(|i| {
            let mut m = MeshTransport::new(
                i, p, Duration::from_millis(100));
            m.set_stats(stats.clone());
            m
        })
        .collect();
    for a in 0..p {
        for b in a + 1..p {
            let (ea, eb) = channel_edge(a, b);
            nodes[a].add_edge(b, Box::new(ea));
            nodes[b].add_edge(a, Box::new(eb));
        }
    }
    let row = share_row(d)?;
    for layer in 0..layers {
        for w in 0..p {
            for to in 0..p {
                if to != w {
                    nodes[w]
                        .send(to, Msg::Exchange {
                            epoch: 0,
                            layer: layer as u32,
                            from: w as u32,
                            data: row.clone(),
                        })
                        .map_err(|e| anyhow!("send: {e}"))?;
                }
            }
        }
        for node in nodes.iter_mut().take(p) {
            for _ in 0..p - 1 {
                recv_ms(node, 200)?;
            }
        }
    }
    Ok(stats.total_bytes())
}

/// The same exchange over the pre-mesh star: workers only talk to the
/// master (id P), which forwards each share to every *other* worker —
/// each delivered share costs two real link crossings. Returns the
/// measured wire bytes.
fn run_hub_exchange(p: usize, d: usize, layers: usize) -> Result<usize> {
    let master_id = p;
    let stats = NetStats::new(p + 1);
    let mut hub = MeshTransport::new(master_id, p + 1,
                                     Duration::from_millis(100));
    hub.set_stats(stats.clone());
    let mut workers: Vec<MeshTransport> = (0..p)
        .map(|i| {
            let mut m = MeshTransport::new(
                i, p + 1, Duration::from_millis(100));
            m.set_stats(stats.clone());
            m
        })
        .collect();
    for (w, worker) in workers.iter_mut().enumerate() {
        let (em, ew) = channel_edge(master_id, w);
        hub.add_edge(w, Box::new(em));
        worker.add_edge(master_id, Box::new(ew));
    }
    let row = share_row(d)?;
    for layer in 0..layers {
        // uplink: the legacy protocol addresses each peer separately —
        // `for to in live { send(to, share) }` — and over a star every
        // one of those sends is a physical frame to the relay
        for (w, worker) in workers.iter_mut().enumerate() {
            for _to in 0..p - 1 {
                worker
                    .send(master_id, Msg::Exchange {
                        epoch: 0,
                        layer: layer as u32,
                        from: w as u32,
                        data: row.clone(),
                    })
                    .map_err(|e| anyhow!("uplink: {e}"))?;
            }
        }
        // relay: the master forwards sender w's k-th copy to the k-th
        // worker that is not w (deterministic addressing stand-in)
        let mut seen = vec![0usize; p];
        for _ in 0..p * (p - 1) {
            let msg = recv_ms(&mut hub, 200)?;
            let Msg::Exchange { from, .. } = &msg else {
                anyhow::bail!("hub wanted an Exchange");
            };
            let from = *from as usize;
            let to = (0..p)
                .filter(|&t| t != from)
                .nth(seen[from])
                .expect("copy count exceeds recipients");
            seen[from] += 1;
            hub.send(to, msg).map_err(|e| anyhow!("relay: {e}"))?;
        }
        for worker in workers.iter_mut() {
            for _ in 0..p - 1 {
                recv_ms(worker, 200)?;
            }
        }
    }
    Ok(stats.total_bytes())
}

fn main() -> Result<()> {
    let (d, layers) = (64usize, 4usize);
    let share = d * 4;
    println!("== mesh vs hub exchange bytes (D={d}, {layers} layers, \
              both measured) ==");
    let mut rows: Vec<Json> = Vec::new();
    for p in 2..=4usize {
        let mesh = run_mesh_exchange(p, d, layers)?;
        let hub = run_hub_exchange(p, d, layers)?;
        // the analytical accounting matches both measurements...
        assert_eq!(mesh, layers * mesh_exchange_bytes(p, share),
                   "P={p}: measured mesh bytes diverge from the model");
        assert_eq!(hub, layers * hub_exchange_bytes(p, share),
                   "P={p}: measured hub bytes diverge from the model");
        // ...and the headline holds between the two *measurements*
        assert!(mesh * 2 <= hub,
                "P={p}: mesh {mesh} B must be at most half the \
                 measured hub relay's {hub} B");
        println!("P={p}: mesh {mesh:>8} B | hub relay {hub:>8} B | \
                  {:.2}x less", hub as f64 / mesh as f64);
        let mut obj: BTreeMap<String, Json> = BTreeMap::new();
        obj.insert("p".into(), Json::Num(p as f64));
        obj.insert("mesh_bytes".into(), Json::Num(mesh as f64));
        obj.insert("hub_bytes".into(), Json::Num(hub as f64));
        obj.insert("reduction".into(),
                   Json::Num(hub as f64 / mesh as f64));
        rows.push(Json::Obj(obj));
    }
    println!("contract: measured mesh exchange <= half the measured \
              hub relay at every P");
    let mut top: BTreeMap<String, Json> = BTreeMap::new();
    top.insert("bench".into(), Json::Str("mesh_bytes".into()));
    top.insert("d".into(), Json::Num(d as f64));
    top.insert("layers".into(), Json::Num(layers as f64));
    top.insert("rows".into(), Json::Arr(rows));
    let path = "BENCH_mesh_bytes.json";
    std::fs::write(path, Json::Obj(top).dump())?;
    println!("json    : {path}");
    Ok(())
}
