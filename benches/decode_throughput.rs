//! §Perf decode bench: incremental `DecodeSession` vs full-recompute
//! autoregressive decoding on the reference backend (artifact-free, so
//! this runs on any checkout).
//!
//! Reports tokens/sec and wire bytes per generated token at the
//! acceptance geometry P=2, L=4, and checks the decode subsystem's
//! contract: >= 5x fewer exchanged bytes per token than full recompute.
//!
//!     cargo bench --bench decode_throughput

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;
use prism::bench_util::bench;
use prism::decode::{full_recompute_bytes_per_token, DecodeSession, RefCfg,
                    RefGpt};
use prism::util::json::Json;
use prism::util::quant::WireFmt;

fn main() -> Result<()> {
    let cfg = RefCfg {
        vocab: 56,
        n: 128,
        d: 64,
        heads: 4,
        layers: 4,
        ffn: 128,
    };
    let (p, l) = (2usize, 4usize);
    let wire = WireFmt::F32;
    let prompt: Vec<i32> = (0..8).map(|i| (i % 50) + 1).collect();
    let steps = 24usize;
    let model = Arc::new(RefGpt::tiny(31, cfg)?);

    println!("== decode throughput (reference backend, N={} d={} \
              layers={} P={p} L={l}) ==", cfg.n, cfg.d, cfg.layers);

    // correctness gate first: identical token streams.
    let (full_toks, _) =
        model.greedy_decode_full(&prompt, steps, p, l, wire)?;
    let mut sess = DecodeSession::new(model.clone(), p, l, wire)?;
    sess.prefill(&prompt)?;
    let inc_toks: Vec<i32> =
        (0..steps).map(|_| sess.generate_next()).collect::<Result<_>>()?;
    assert_eq!(inc_toks, full_toks,
               "incremental decode diverged from full recompute");
    println!("correctness : incremental == full recompute \
              ({steps}/{steps} tokens)");

    // tokens/sec: full recompute re-runs the whole window per token.
    let full_stats = bench(1, 5, || {
        model
            .greedy_decode_full(&prompt, steps, p, l, wire)
            .unwrap();
    });
    let full_tps = steps as f64 / full_stats.median_secs;
    println!("full recompute : {} | {:.1} tok/s", full_stats.per_op(),
             full_tps);

    let inc_stats = bench(1, 5, || {
        let mut s = DecodeSession::new(model.clone(), p, l, wire).unwrap();
        s.prefill(&prompt).unwrap();
        for _ in 0..steps {
            s.generate_next().unwrap();
        }
    });
    let inc_tps = steps as f64 / inc_stats.median_secs;
    println!("incremental    : {} | {:.1} tok/s ({:.1}x faster)",
             inc_stats.per_op(), inc_tps, inc_tps / full_tps);

    // bytes per generated token (prefill charged to the session).
    let st = sess.stats();
    let inc_total = st.wire_bytes();
    let full_per_tok =
        full_recompute_bytes_per_token(cfg.layers, p, l, cfg.d, wire);
    let full_total = full_per_tok * steps;
    let ratio = full_total as f64 / inc_total as f64;
    println!("bytes/token    : incremental {:.0} (total {inc_total} incl. \
              prefill) vs full {full_per_tok} (total {full_total})",
             inc_total as f64 / steps as f64);
    println!("byte reduction : {ratio:.1}x");
    assert!(
        ratio >= 5.0,
        "decode subsystem contract: >= 5x fewer exchanged bytes per \
         token at P=2 L=4 (got {ratio:.2}x)"
    );
    println!("contract       : >= 5x fewer bytes/token OK");

    // machine-readable record for the CI perf-trajectory artifact
    // (uploaded as BENCH_*.json per PR)
    let mut obj: BTreeMap<String, Json> = BTreeMap::new();
    obj.insert("bench".into(), Json::Str("decode_throughput".into()));
    obj.insert("p".into(), Json::Num(p as f64));
    obj.insert("l".into(), Json::Num(l as f64));
    obj.insert("steps".into(), Json::Num(steps as f64));
    obj.insert("full_tok_per_s".into(), Json::Num(full_tps));
    obj.insert("incremental_tok_per_s".into(), Json::Num(inc_tps));
    obj.insert("speedup".into(), Json::Num(inc_tps / full_tps));
    obj.insert("incremental_total_bytes".into(),
               Json::Num(inc_total as f64));
    obj.insert("full_total_bytes".into(), Json::Num(full_total as f64));
    obj.insert("byte_reduction".into(), Json::Num(ratio));
    let path = "BENCH_decode_throughput.json";
    std::fs::write(path, Json::Obj(obj).dump())?;
    println!("json           : {path}");
    Ok(())
}
