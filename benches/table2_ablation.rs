//! Reproduces paper Table II: impact of *duplicated* Segment-Means vectors
//! on ViT self-attention accuracy (P = 2, three landmark budgets).
//!
//! "No" runs the scaling-aware softmax with g = 1 (segment means used
//! once); "Yes" uses the repetition counts (the ln g bias) — the paper's
//! duplication strategy without the duplicated FLOPs.

use anyhow::Result;

use prism::bench_util::{eval_limit, require_artifacts};
use prism::coordinator::plan::effective_cr;
use prism::coordinator::{Mode, Runner};
use prism::data::Dataset;
use prism::eval::{evaluate, EvalOpts};
use prism::metrics::report::{f2, pct, Table};
use prism::runtime::WeightSet;

fn main() -> Result<()> {
    let Some(m) = require_artifacts() else { return Ok(()) };
    let limit = eval_limit(256);
    let n = m.model("vit")?.n;
    let ds = Dataset::load(&m.root, "synth10")?;
    let ws = WeightSet::load(&m, "vit_synth10")?;
    let mut runner = Runner::new(m.clone(), "xla")?;

    let mut table = Table::new(
        "Table II — duplicated Segment Means ablation (ViT, synth10, P=2)",
        &["P", "PDPLC", "CR", "Acc (No dup)", "Acc (Yes dup)"],
    );
    for l in [3usize, 6, 10] {
        let mut accs = Vec::new();
        for duplicated in [false, true] {
            let mode = Mode::Prism { p: 2, l, duplicated };
            let res = evaluate(&mut runner, &ws, &ds,
                               &EvalOpts { mode, limit })?;
            eprintln!("  [L={l} dup={duplicated}] acc {:.4} ({:.1}s)",
                      res.metric, res.total_secs);
            accs.push(res.metric);
        }
        table.row(vec![
            "2".into(),
            l.to_string(),
            f2(effective_cr(n, 2, l)),
            pct(accs[0]),
            pct(accs[1]),
        ]);
    }
    table.print();

    // Same ablation on the PRISM-finetuned weights (trained WITH the
    // repetition counts in the loop): duplication decisively wins here —
    // the train/infer-consistency side of the paper's Table II claim.
    let ws_ft = WeightSet::load(&m, "vit_synth10_ft")?;
    let mut ft = Table::new(
        "Table II (b) — same ablation, PRISM-finetuned weights (P=3, \
         finetuned at L=3)",
        &["P", "PDPLC", "CR", "Acc (No dup)", "Acc (Yes dup)"],
    );
    for l in [3usize, 5, 10] {
        let mut accs = Vec::new();
        for duplicated in [false, true] {
            let mode = Mode::Prism { p: 3, l, duplicated };
            let res = evaluate(&mut runner, &ws_ft, &ds,
                               &EvalOpts { mode, limit })?;
            accs.push(res.metric);
        }
        ft.row(vec![
            "3".into(),
            (2 * l).to_string(),
            f2(effective_cr(n, 3, l)),
            pct(accs[0]),
            pct(accs[1]),
        ]);
    }
    ft.print();
    println!("\npaper reference (Table II, N=197): PDPLC 10 -> 91.66 vs \
              95.64; PDPLC 20 -> 95.4 vs 96.84; PDPLC 30 -> 96.48 vs \
              97.06 (duplication always helps, gap shrinks as L grows).\n\
              Observed divergence: on the tiny from-scratch model the \
              naive (no-dup) variant wins zero-shot — the synthetic task \
              is locally decodable, so down-weighting the compressed \
              context helps; once the model is finetuned with the \
              scaling-aware softmax in the loop (table b — the realistic \
              deployment path), duplication wins by a wide margin, \
              matching the paper's direction.");
    Ok(())
}
