//! Ablations beyond the paper's tables (DESIGN.md design-choice index):
//!
//!   (a) context compressor — Segment Means vs rate-matched baselines
//!       (center token, first token, global mean) at equal CR;
//!   (b) wire precision — f32 vs f16 vs int8 landmark exchange: accuracy
//!       vs additional communication speed-up;
//!   (c) heterogeneous devices — Algorithm-1 equal split vs
//!       speed-weighted partitioning under a 2x-slower straggler
//!       (paper-scale latency model).

use anyhow::Result;

use prism::bench_util::{eval_limit, require_artifacts};
use prism::coordinator::plan::weighted_partition_sizes;
use prism::coordinator::{Compressor, Mode, Runner};
use prism::data::Dataset;
use prism::eval::{evaluate, EvalOpts};
use prism::metrics::report::{f2, pct, Table};
use prism::model::flops;
use prism::model::paper::VIT_BASE;
use prism::net::{LinkModel, SimClock};
use prism::runtime::WeightSet;
use prism::util::quant::WireFmt;

fn main() -> Result<()> {
    let Some(m) = require_artifacts() else { return Ok(()) };
    let limit = eval_limit(192);
    let ds = Dataset::load(&m.root, "synth10")?;
    let ws = WeightSet::load(&m, "vit_synth10")?;
    let ws_ft = WeightSet::load(&m, "vit_synth10_ft")?;
    let mut runner = Runner::new(m.clone(), "xla")?;
    let mode = Mode::Prism { p: 2, l: 6, duplicated: true };

    // (a) compressor ablation -------------------------------------------
    let mut ta = Table::new(
        "(a) context compressor @ equal rate (ViT synth10, P=2, L=6)",
        &["compressor", "acc (base)", "acc (finetuned)"],
    );
    for comp in [Compressor::SegmentMeans, Compressor::CenterToken,
                 Compressor::FirstToken, Compressor::GlobalMean] {
        runner.compressor = comp;
        let a = evaluate(&mut runner, &ws, &ds,
                         &EvalOpts { mode, limit })?;
        let b = evaluate(&mut runner, &ws_ft, &ds,
                         &EvalOpts { mode, limit })?;
        eprintln!("  [{}] base {:.4} ft {:.4}", comp.name(), a.metric,
                  b.metric);
        ta.row(vec![comp.name().into(), pct(a.metric), pct(b.metric)]);
    }
    runner.compressor = Compressor::SegmentMeans;
    ta.print();
    println!();

    // (b) wire precision -------------------------------------------------
    let mut tb = Table::new(
        "(b) landmark wire precision (ViT synth10, P=2, L=6)",
        &["wire", "acc (base)", "acc (finetuned)", "B/dev/layer",
          "extra comm speed-up"],
    );
    let f32_bytes = 6 * 128 * 4; // L * D * 4
    for wire in [WireFmt::F32, WireFmt::F16, WireFmt::I8] {
        runner.wire = wire;
        let a = evaluate(&mut runner, &ws, &ds,
                         &EvalOpts { mode, limit })?;
        let b = evaluate(&mut runner, &ws_ft, &ds,
                         &EvalOpts { mode, limit })?;
        let bytes = wire.wire_bytes(6 * 128, 6);
        eprintln!("  [{wire:?}] base {:.4} ft {:.4}", a.metric, b.metric);
        tb.row(vec![
            format!("{wire:?}"),
            pct(a.metric),
            pct(b.metric),
            bytes.to_string(),
            format!("{:.1}x", f32_bytes as f64 / bytes as f64),
        ]);
    }
    runner.wire = WireFmt::F32;
    tb.print();
    println!();

    // (c) heterogeneous partitioning (paper-scale latency model) --------
    let host = 8.0; // GFLOPS; relative comparison, absolute irrelevant
    let speeds = [1.0, 0.5]; // device 1 is a 2x-slower straggler
    let mut tc = Table::new(
        "(c) straggler (device 1 at 0.5x): equal vs speed-weighted split \
         (ViT-Base scale, P=2, L=10, 200 Mbps)",
        &["split", "sizes", "latency (s)"],
    );
    for (label, sizes) in [
        ("Algorithm 1 (equal)", vec![98usize, 99]),
        ("speed-weighted",
         weighted_partition_sizes(197, &speeds)?),
    ] {
        let mut clock = SimClock::new(2, LinkModel::new(200.0, 2.0));
        let l = 10usize;
        for _ in 0..VIT_BASE.layers {
            for d in 0..2 {
                let np = sizes[d];
                let f = flops::block_flops(&VIT_BASE, np, np + l);
                clock.compute(d, f / (host * speeds[d] * 1e9));
            }
            clock.exchange_all(&[l * VIT_BASE.d * 4; 2]);
        }
        tc.row(vec![label.into(), format!("{sizes:?}"),
                    f2(clock.makespan())]);
    }
    tc.print();
    println!("\nReading: (a) Segment Means should dominate the \
              rate-matched token-subsampling and global-mean baselines — \
              the paper's compressor carries more context per byte; (b) \
              f16 is accuracy-free and doubles the comm win, int8 \
              quarters bytes with a small hit; (c) speed-weighted \
              partitioning removes the straggler's share of the barrier \
              wait.");
    Ok(())
}
