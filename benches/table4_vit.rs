//! Reproduces paper Table IV: computation and communication efficiency for
//! the ViT model.
//!
//! GFLOPs columns are analytical at the paper's full scale (ViT-Base,
//! N = 197 — the paper's own convention; validated against every Table IV
//! entry in `model::flops` unit tests), mapped from each tiny variant's
//! compression rate via Eq. 16. Accuracy columns are *measured* end-to-end
//! on the AOT artifacts over the CIFAR-10/100/ImageNet stand-ins.
//!
//! `PRISM_EVAL_LIMIT` caps evaluated samples (default 256).

use anyhow::Result;

use prism::bench_util::{eval_limit, require_artifacts};
use prism::coordinator::plan::{effective_cr, landmarks_for_cr};
use prism::coordinator::{Mode, Runner};
use prism::data::Dataset;
use prism::eval::{evaluate, EvalOpts};
use prism::metrics::report::{f2, opt, pct, Table};
use prism::model::paper::VIT_BASE;
use prism::model::{comm, flops};
use prism::runtime::WeightSet;

const DATASETS: [&str; 3] = ["synth10", "synth100", "synthhard"];

struct Row {
    label: &'static str,
    mode: Mode,
    finetuned: bool,
}

fn main() -> Result<()> {
    let Some(m) = require_artifacts() else { return Ok(()) };
    let limit = eval_limit(256);
    let n = m.model("vit")?.n;

    let rows = vec![
        Row { label: "No partition", mode: Mode::Single, finetuned: false },
        Row { label: "Voltage", mode: Mode::Voltage { p: 2 },
              finetuned: false },
        Row { label: "Voltage", mode: Mode::Voltage { p: 3 },
              finetuned: false },
        Row { label: "PRISM",
              mode: Mode::Prism { p: 2, l: 3, duplicated: true },
              finetuned: false },
        Row { label: "PRISM",
              mode: Mode::Prism { p: 2, l: 6, duplicated: true },
              finetuned: false },
        Row { label: "PRISM",
              mode: Mode::Prism { p: 2, l: 10, duplicated: true },
              finetuned: false },
        Row { label: "PRISM",
              mode: Mode::Prism { p: 3, l: 3, duplicated: true },
              finetuned: false },
        Row { label: "PRISM",
              mode: Mode::Prism { p: 3, l: 5, duplicated: true },
              finetuned: false },
        Row { label: "PRISM",
              mode: Mode::Prism { p: 3, l: 10, duplicated: true },
              finetuned: false },
        Row { label: "PRISM (Finetuned)",
              mode: Mode::Prism { p: 3, l: 3, duplicated: true },
              finetuned: true },
    ];

    let mut runner = Runner::new(m.clone(), "xla")?;
    let datasets: Vec<Dataset> = DATASETS
        .iter()
        .map(|d| Dataset::load(&m.root, d))
        .collect::<Result<_>>()?;

    let mut table = Table::new(
        "Table IV — ViT computation & communication efficiency \
         (GFLOPs at paper scale; accuracy measured)",
        &["Strategy", "P", "GFLOPs", "GFLOPs/dev", "CompSU%", "PDPLC",
          "CR", "CommSU%", "synth10", "synth100", "synthhard"],
    );
    let single = flops::single_total(&VIT_BASE);
    for row in &rows {
        let p = row.mode.p();
        // map the tiny variant's CR to the paper-scale landmark count
        let (total, per_dev, pdplc, cr, comm_su) = match row.mode {
            Mode::Single => (single, single, None, None, None),
            Mode::Voltage { p } => {
                let t = flops::voltage_total(&VIT_BASE, p);
                (t, t / p as f64,
                 Some(comm::pdplc_tokens_voltage(VIT_BASE.n, p) as f64),
                 None, None)
            }
            Mode::Prism { p, l, .. } => {
                let cr = effective_cr(n, p, l);
                let lp = landmarks_for_cr(VIT_BASE.n, p, cr);
                let t = flops::prism_total(&VIT_BASE, p, lp);
                (t, t / p as f64,
                 Some(comm::pdplc_tokens_prism(p, lp) as f64), Some(cr),
                 Some(comm::comm_speedup(VIT_BASE.n, p, lp)))
            }
        };
        let mut accs = Vec::new();
        for ds in &datasets {
            let mut tag = format!("vit_{}", ds.name);
            if row.finetuned {
                tag = format!("{tag}_ft");
            }
            let ws = WeightSet::load(&m, &tag)?;
            let res = evaluate(&mut runner, &ws, ds,
                               &EvalOpts { mode: row.mode, limit })?;
            accs.push(pct(res.metric));
            eprintln!("  [{}{} p={p}] {} -> {:.4} ({} samples, {:.1}s)",
                      row.label, if row.finetuned { "-ft" } else { "" },
                      ds.name, res.metric, res.samples, res.total_secs);
        }
        table.row(vec![
            row.label.to_string(),
            p.to_string(),
            f2(total / 1e9),
            f2(per_dev / 1e9),
            if matches!(row.mode, Mode::Single) { "-".into() }
            else { pct(flops::comp_speedup(per_dev, single)) },
            opt(pdplc, |v| format!("{v:.0}")),
            opt(cr, f2),
            opt(comm_su, pct),
            accs[0].clone(),
            accs[1].clone(),
            accs[2].clone(),
        ]);
    }
    table.print();
    println!("\npaper reference (Table IV): No-partition 35.15 GFLOPs / \
              acc 98.01, 91.00, 80.30; Voltage P=2 40.74, P=3 46.33 \
              (acc unchanged); PRISM P=2 CR=9.9 -> 89.9% comm speed-up, \
              acc 95.64/85.25/72.64; finetuning recovers most accuracy.");
    Ok(())
}
