//! §Perf soak bench: the deterministic full-stack soak harness
//! (`sim::run_soak` — real serving loops on the virtual clock) at a
//! fixed seed, reporting virtual-time throughput and latency
//! percentiles plus the wall cost of simulating it.
//!
//! Artifact-free (the sim's stand-in blocks need no AOT artifacts), so
//! this runs on any checkout:
//!
//!     cargo bench --bench soak_throughput

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;
use prism::sim::{run_soak, SoakCfg};
use prism::util::json::Json;

fn main() -> Result<()> {
    let mut cfg = SoakCfg::small(11);
    cfg.workload.requests = 2000;
    println!("== soak throughput (virtual clock, P={} L={}, {} mixed \
              requests, kill/re-join churn) ==",
             cfg.p, cfg.l, cfg.workload.requests);

    let t0 = Instant::now();
    let report = run_soak(&cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    // contract: the soak is drop-free and ends at full strength
    assert_eq!(report.dropped(), 0, "soak dropped requests");
    assert_eq!(report.final_p, cfg.p, "soak did not restore full P");
    assert!(report.virtual_secs > 0.0);
    // and simulating it costs seconds, not the virtual timeline
    assert!(wall < 60.0, "soak bench too slow: {wall:.1}s wall");

    let req_per_vs = report.requests() as f64 / report.virtual_secs;
    let eval_p50_ms = report.eval_latency.p50() * 1e3;
    let eval_p99_ms = report.eval_latency.p99() * 1e3;
    let dec_p50_ms = report.decode_latency.p50() * 1e3;
    let dec_p99_ms = report.decode_latency.p99() * 1e3;
    println!("requests   : {} eval + {} decode streams ({} tokens)",
             report.eval_requests, report.decode_streams,
             report.decode_tokens);
    println!("virtual    : {:.2}s ({req_per_vs:.1} req/s), {} epochs, \
              {} wire bytes", report.virtual_secs, report.final_epoch,
             report.wire_bytes);
    println!("eval lat   : p50 {eval_p50_ms:.2}ms p99 \
              {eval_p99_ms:.2}ms");
    println!("decode lat : p50 {dec_p50_ms:.2}ms p99 {dec_p99_ms:.2}ms");
    println!("wall       : {wall:.2}s to simulate \
              ({:.0}x faster than the virtual timeline)",
             report.virtual_secs / wall.max(1e-9));

    // machine-readable record for the CI perf-trajectory artifact
    // (uploaded as BENCH_*.json per PR)
    let mut obj: BTreeMap<String, Json> = BTreeMap::new();
    obj.insert("bench".into(), Json::Str("soak_throughput".into()));
    obj.insert("seed".into(), Json::Num(cfg.seed as f64));
    obj.insert("requests".into(),
               Json::Num(report.requests() as f64));
    obj.insert("virtual_secs".into(),
               Json::Num(report.virtual_secs));
    obj.insert("wall_secs".into(), Json::Num(wall));
    obj.insert("req_per_virtual_sec".into(), Json::Num(req_per_vs));
    obj.insert("eval_p50_ms".into(), Json::Num(eval_p50_ms));
    obj.insert("eval_p99_ms".into(), Json::Num(eval_p99_ms));
    obj.insert("decode_p50_ms".into(), Json::Num(dec_p50_ms));
    obj.insert("decode_p99_ms".into(), Json::Num(dec_p99_ms));
    obj.insert("final_epoch".into(),
               Json::Num(report.final_epoch as f64));
    obj.insert("wire_bytes".into(),
               Json::Num(report.wire_bytes as f64));
    let path = "BENCH_soak.json";
    std::fs::write(path, Json::Obj(obj).dump())?;
    println!("json       : {path}");
    Ok(())
}
