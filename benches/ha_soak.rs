//! §Perf HA bench: the master-kill soak (`SoakCfg::ha`) against its
//! no-kill twin — promotion latency in *virtual* milliseconds (paced by
//! the gossip suspicion deadband, so it is a protocol number, not a
//! machine number), zero dropped requests across the failover, stream
//! digest parity with the twin, and the decode/eval latency tails the
//! failover costs.
//!
//! Everything runs on the virtual clock, so every reported number is
//! deterministic for the pinned seed and machine-independent — which is
//! what lets `scripts/bench_gate` hard-gate them in
//! `bench_baseline.json`.
//!
//! Artifact-free (the sim's stand-in blocks need no AOT artifacts), so
//! this runs on any checkout:
//!
//!     cargo bench --bench ha_soak

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;
use prism::sim::{run_soak, SoakCfg};
use prism::util::json::Json;

fn main() -> Result<()> {
    let cfg = SoakCfg::ha(11);
    let ha = cfg.ha.expect("HA preset arms gossip + state-sync");
    println!("== HA soak (virtual clock, {} requests, master killed \
              mid-run, gossip every {:?} x {} deadband) ==",
             cfg.workload.requests, ha.gossip_every, ha.suspect_after);

    let t0 = Instant::now();
    let kill = run_soak(&cfg)?;
    let twin = run_soak(&SoakCfg::ha_no_kill(11))?;
    let wall = t0.elapsed().as_secs_f64();

    // contract: exactly one kill and one promotion, nothing dropped,
    // streams bit-identical to the twin, no false promotion twin-side
    assert_eq!(kill.master_kills, 1, "preset must kill the master");
    assert_eq!(kill.promotions, 1, "standby must promote exactly once");
    assert_eq!(kill.dropped(), 0, "requests dropped across failover");
    assert_eq!(twin.promotions, 0, "no-kill twin promoted (deadband \
                                    false positive)");
    assert_eq!(twin.dropped(), 0, "twin dropped requests");
    let digest_mismatches = kill
        .stream_digests
        .iter()
        .filter(|(id, d)| twin.stream_digests.get(id) != Some(d))
        .count()
        + twin
            .stream_digests
            .keys()
            .filter(|id| !kill.stream_digests.contains_key(id))
            .count();
    assert_eq!(digest_mismatches, 0,
               "decode streams diverged across the failover");
    let promotion_ms = kill.promotion_latency[0] * 1e3;
    let window_ms = ha.gossip_every.as_secs_f64()
        * ha.suspect_after as f64 * 1e3;
    assert!(wall < 120.0, "HA bench too slow: {wall:.1}s wall");

    println!("promotion   : {promotion_ms:8.1}ms virtual (suspicion \
              window {window_ms:.0}ms)");
    println!("dropped     : {:8} of {} admitted requests",
             kill.dropped(), kill.requests());
    println!("streams     : {:8} digests, {digest_mismatches} \
              mismatches vs no-kill twin",
             kill.stream_digests.len());
    println!("carryover   : {:8} re-admitted from snapshot, {} \
              client re-sends",
             kill.readmitted_streams, kill.resubmitted_streams);
    println!("decode p99  : {:8.2}ms (kill) vs {:8.2}ms (twin)",
             kill.decode_latency.p99() * 1e3,
             twin.decode_latency.p99() * 1e3);
    println!("eval p99    : {:8.2}ms (kill) vs {:8.2}ms (twin)",
             kill.eval_latency.p99() * 1e3,
             twin.eval_latency.p99() * 1e3);
    println!("wall        : {wall:.2}s to simulate both runs");

    let mut obj: BTreeMap<String, Json> = BTreeMap::new();
    obj.insert("bench".into(), Json::Str("ha_soak".into()));
    obj.insert("seed".into(), Json::Num(cfg.seed as f64));
    obj.insert("requests".into(), Json::Num(kill.requests() as f64));
    obj.insert("promotion_ms".into(), Json::Num(promotion_ms));
    obj.insert("suspicion_window_ms".into(), Json::Num(window_ms));
    obj.insert("dropped".into(), Json::Num(kill.dropped() as f64));
    obj.insert("digest_mismatches".into(),
               Json::Num(digest_mismatches as f64));
    obj.insert("readmitted_streams".into(),
               Json::Num(kill.readmitted_streams as f64));
    obj.insert("resubmitted_streams".into(),
               Json::Num(kill.resubmitted_streams as f64));
    obj.insert("decode_p99_ms".into(),
               Json::Num(kill.decode_latency.p99() * 1e3));
    obj.insert("twin_decode_p99_ms".into(),
               Json::Num(twin.decode_latency.p99() * 1e3));
    obj.insert("eval_p99_ms".into(),
               Json::Num(kill.eval_latency.p99() * 1e3));
    obj.insert("wall_secs".into(), Json::Num(wall));
    let path = "BENCH_ha.json";
    std::fs::write(path, Json::Obj(obj).dump())?;
    println!("json        : {path}");
    Ok(())
}
