//! Vendored offline stub of the `xla` (PJRT) bindings.
//!
//! Mirrors the API surface `prism::runtime::engine` compiles against:
//! `PjRtClient`, `PjRtLoadedExecutable`, `PjRtBuffer`, `Literal`,
//! `HloModuleProto`, `XlaComputation`, `Error`. Host-side literal
//! handling works for real; anything that would need the PJRT runtime
//! (loading HLO text, compiling, executing) returns a descriptive
//! error, so artifact-backed paths fail with "stub xla backend" and the
//! artifact-free paths (all unit tests, the decode subsystem, the
//! reference model) run normally. Deployments swap in the real crate
//! via the root Cargo.toml; no prism source change is needed.

use std::fmt;
use std::path::Path;

const STUB: &str = "stub xla backend (vendored third_party/xla): PJRT is \
                    unavailable in this build; install the real `xla` \
                    crate to run AOT artifacts";

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Element types a `Literal` can hold (f32 / i32 are all PRISM moves
/// across the AOT boundary).
pub trait NativeType: Copy {
    fn literal_from(v: &[Self]) -> Literal;
    fn extract(lit: &Literal) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn literal_from(v: &[Self]) -> Literal {
        Literal { data: Data::F32(v.to_vec()), dims: vec![v.len() as i64] }
    }

    fn extract(lit: &Literal) -> Option<Vec<Self>> {
        match &lit.data {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn literal_from(v: &[Self]) -> Literal {
        Literal { data: Data::I32(v.to_vec()), dims: vec![v.len() as i64] }
    }

    fn extract(lit: &Literal) -> Option<Vec<Self>> {
        match &lit.data {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host-side literal: dense data + dims (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        T::literal_from(v)
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = match &self.data {
            Data::F32(v) => v.len() as i64,
            Data::I32(v) => v.len() as i64,
        };
        if want != have {
            return Err(Error(format!(
                "reshape to {dims:?} wants {want} elements, literal has \
                 {have}"
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self).ok_or_else(|| {
            Error("literal element type mismatch".to_string())
        })
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error(STUB.to_string()))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(Error(format!("{STUB}; cannot load HLO '{}'",
                          path.as_ref().display())))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(STUB.to_string()))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(STUB.to_string()))
    }
}

pub struct PjRtClient;

impl PjRtClient {
    /// Succeeds so engines can be constructed; only artifact execution
    /// is unavailable.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(Error(STUB.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 3]).is_err());
        let i = Literal::vec1(&[7i32]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn runtime_paths_report_stub() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-cpu");
        let e = HloModuleProto::from_text_file("/tmp/x.hlo").unwrap_err();
        assert!(e.to_string().contains("stub xla backend"));
        assert!(c.compile(&XlaComputation).is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
    }
}
