//! Vendored offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this implements the
//! subset PRISM uses with the same surface: `Error` (context chain,
//! `{:#}` alternate formatting), `Result`, the `anyhow!` / `bail!` /
//! `ensure!` macros, and the `Context` extension trait for `Result` and
//! `Option`. Swapping in the real crate is a one-line Cargo.toml change.

use std::fmt;

/// Error with a context chain, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first (mirrors `anyhow::Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        for cause in &self.chain[1..] {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach lazy or eager context to a fallible value.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T, Error>;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T, Error> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn chain_formatting() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }

    #[test]
    fn io_error_converts() {
        let r: Result<String> =
            std::fs::read_to_string("/definitely/not/here")
                .with_context(|| "reading file".to_string());
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.starts_with("reading file: "), "{msg}");
    }

    #[test]
    fn ensure_and_inline_captures() {
        fn check(n: usize) -> Result<usize> {
            ensure!(n > 2, "n too small: {n}");
            Ok(n)
        }
        assert!(check(1).is_err());
        assert_eq!(check(5).unwrap(), 5);
    }
}
