"""Training-infrastructure units: optimizer, param save/load roundtrip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import train as T


def test_adam_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = T.adam_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = T.adam_update(params, g, state, lr=0.1)
    assert float(loss(params)) < 1e-3


def test_adam_state_shapes_match():
    params = {"a": jnp.zeros((2, 3)), "b": [jnp.ones(4)]}
    st = T.adam_init(params)
    assert st["m"]["a"].shape == (2, 3)
    assert st["v"]["b"][0].shape == (4,)
    assert st["t"] == 0


def test_ce_loss_basics():
    lg = jnp.asarray([[10.0, 0.0], [0.0, 10.0]])
    y = jnp.asarray([0, 1])
    assert float(T.ce_loss(lg, y)) < 1e-3
    y_bad = jnp.asarray([1, 0])
    assert float(T.ce_loss(lg, y_bad)) > 5.0


def test_save_load_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setattr(T, "WEIGHTS_DIR", str(tmp_path))
    params = {
        "embed": {"tok": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "blocks": [
            {"wq": jnp.ones((2, 2)), "bq": jnp.zeros(2)},
            {"wq": jnp.full((2, 2), 3.0), "bq": jnp.ones(2)},
        ],
        "head_t": {"w": jnp.zeros((2, 5))},
    }
    T.save_params("x", params)
    assert T.have("x")
    loaded = T.load_params("x")
    assert np.allclose(loaded["embed"]["tok"], params["embed"]["tok"])
    assert np.allclose(loaded["blocks"][1]["wq"], 3.0)
    assert loaded["blocks"][0]["bq"].shape == (2,)
    assert loaded["head_t"]["w"].shape == (2, 5)
    assert not T.have("y")


@pytest.mark.slow
def test_short_vit_training_decreases_loss():
    # 12 steps on the real pipeline: just checks the training graph wires.
    params, acc = T.train_vit("synth10", steps=12, bs=16, log=lambda *_: 0)
    assert 0.0 <= acc <= 1.0
