"""Layer-1 Pallas kernels vs the pure-jnp oracle (hypothesis sweeps)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.prism_attention import (mxu_flops, prism_attention,
                                             vmem_footprint_bytes)
from compile.kernels.ref import (attention_ref, duplicated_attention_ref,
                                 gelu_ref, layernorm_ref,
                                 prism_attention_scaled_ref,
                                 segment_means_ref)
from compile.kernels.segment_means import segment_means
from compile.plan import plans

S = settings(max_examples=25, deadline=None)


def _rand(rng, *shape, scale=1.0):
    return (scale * rng.normal(size=shape)).astype(np.float32)


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 3), st.integers(1, 4),
       st.integers(1, 48), st.integers(1, 48),
       st.sampled_from([4, 8, 16, 32]))
@S
def test_pallas_attention_matches_oracle(seed, b, h, nq, nk, dh):
    rng = np.random.default_rng(seed)
    q = _rand(rng, b, h, nq, dh)
    k = _rand(rng, b, h, nk, dh)
    v = _rand(rng, b, h, nk, dh)
    bias = _rand(rng, nq, nk)
    out = prism_attention(q, k, v, bias)
    ref = attention_ref(q, k, v, bias)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1, 2, 7, 16, 64]))
@S
def test_pallas_attention_block_q_invariant(seed, block_q):
    """Tiling must not change the numbers (HBM↔VMEM schedule only)."""
    rng = np.random.default_rng(seed)
    q = _rand(rng, 2, 2, 33, 16)
    k = _rand(rng, 2, 2, 20, 16)
    v = _rand(rng, 2, 2, 20, 16)
    bias = _rand(rng, 33, 20)
    a = prism_attention(q, k, v, bias, block_q=block_q)
    b = prism_attention(q, k, v, bias, block_q=33)
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_attention_masked_columns_are_ignored():
    rng = np.random.default_rng(0)
    q, k, v = (_rand(rng, 1, 1, 5, 8) for _ in range(3))
    bias = np.zeros((5, 10), np.float32)
    bias[:, 5:] = -1e30
    k2 = np.concatenate([k, _rand(rng, 1, 1, 5, 8)], axis=2)
    v2 = np.concatenate([v, _rand(rng, 1, 1, 5, 8)], axis=2)
    full = prism_attention(q, k2, v2, bias)
    only = prism_attention(q, k, v, np.zeros((5, 5), np.float32))
    np.testing.assert_allclose(full, only, atol=1e-5)


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 3),
       st.integers(1, 70), st.integers(1, 12), st.sampled_from([4, 8, 33]))
@S
def test_pallas_segment_means_matches_oracle(seed, b, n_p, l, d):
    if n_p < l:
        return
    rng = np.random.default_rng(seed)
    x = _rand(rng, b, n_p, d)
    out = segment_means(x, l=l)
    ref = segment_means_ref(x, l)
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


def test_segment_means_constant_preserved():
    x = np.full((2, 13, 5), 3.25, np.float32)
    z = segment_means(x, l=4)
    np.testing.assert_allclose(z, 3.25)


def test_segment_means_identity_when_l_equals_n():
    rng = np.random.default_rng(1)
    x = _rand(rng, 2, 9, 6)
    np.testing.assert_allclose(segment_means(x, l=9), x, atol=0)


# ---- the paper's core algebra: Eq. 13-15 == Eq. 11/12 == softmax(+ln g) --

@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 30), st.integers(1, 10),
       st.integers(1, 8))
@S
def test_scaling_aware_equals_duplicated(seed, nq, nk, maxcount):
    rng = np.random.default_rng(seed)
    counts = rng.integers(1, maxcount + 1, size=nk)
    q = _rand(rng, nq, 8, scale=0.4)
    k = _rand(rng, nk, 8, scale=0.4)
    v = _rand(rng, nk, 8)
    a_scaled = prism_attention_scaled_ref(q, k, v,
                                          counts.astype(np.float32))
    a_dup = duplicated_attention_ref(q, k, v, counts)
    np.testing.assert_allclose(a_scaled, a_dup, atol=1e-5, rtol=1e-4)


@given(st.integers(0, 2 ** 31 - 1))
@S
def test_scaling_aware_equals_log_bias_form(seed):
    """softmax(logits + ln g) == rownorm(exp(logits) ⊙ g): what the AOT
    executables actually compute vs the paper's literal Eq. 13-15."""
    rng = np.random.default_rng(seed)
    g = rng.integers(1, 12, size=17).astype(np.float32)
    q = _rand(rng, 9, 8, scale=0.4)
    k = _rand(rng, 17, 8, scale=0.4)
    v = _rand(rng, 17, 8)
    a1 = prism_attention_scaled_ref(q, k, v, g)
    a2 = attention_ref(q, k, v, jnp.log(g)[None, :])
    np.testing.assert_allclose(a1, a2, atol=1e-5, rtol=1e-4)


@given(st.integers(0, 2 ** 31 - 1))
@S
def test_permutation_invariance_eq5(seed):
    """Eq. 5: attention is invariant to a joint permutation of K/V rows
    (with bias columns permuted alongside) — the property that makes
    out-of-order Segment-Means delivery safe."""
    rng = np.random.default_rng(seed)
    q = _rand(rng, 1, 2, 7, 8)
    k = _rand(rng, 1, 2, 13, 8)
    v = _rand(rng, 1, 2, 13, 8)
    bias = _rand(rng, 7, 13)
    perm = rng.permutation(13)
    a = prism_attention(q, k, v, bias)
    b = prism_attention(q, k[:, :, perm], v[:, :, perm], bias[:, perm])
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_scaling_aware_with_plan_geometry():
    """End-to-end over a real plan: scaled form vs duplicating each peer
    segment mean back to its segment length (Table II's 'Yes' column)."""
    rng = np.random.default_rng(3)
    for p, l in ((2, 3), (3, 4)):
        for pl in plans(65, p, l, False):
            g = pl.g()
            q = _rand(rng, pl.n_p, 16, scale=0.3)
            k = _rand(rng, pl.n_hat, 16, scale=0.3)
            v = _rand(rng, pl.n_hat, 16)
            a1 = prism_attention_scaled_ref(q, k, v, g)
            a2 = duplicated_attention_ref(q, k, v, g.astype(int))
            np.testing.assert_allclose(a1, a2, atol=1e-5, rtol=1e-4)


def test_layernorm_and_gelu_sanity():
    rng = np.random.default_rng(0)
    x = _rand(rng, 4, 9)
    y = layernorm_ref(x, np.ones(9, np.float32), np.zeros(9, np.float32))
    np.testing.assert_allclose(np.mean(y, -1), 0, atol=1e-5)
    np.testing.assert_allclose(np.var(np.asarray(y), -1), 1, atol=1e-3)
    assert float(gelu_ref(jnp.asarray(0.0))) == 0.0
    assert float(gelu_ref(jnp.asarray(10.0))) > 9.99


def test_perf_model_helpers():
    # VMEM estimate must scale with Nk (the PRISM win) and stay < 16 MiB
    small = vmem_footprint_bytes(33, 39, 32)
    big = vmem_footprint_bytes(33, 330, 32)
    assert small < big < 16 * 2 ** 20
    assert mxu_flops(10, 20, 32) == 2 * 10 * 20 * 32 * 2
