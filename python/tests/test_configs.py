"""Variant enumeration / CR bookkeeping (the manifest contract)."""

import pytest

from compile.configs import (BERT, GPT2, MODELS, VIT, Variant,
                             all_variants, bert_variants, effective_cr,
                             gpt2_variants, landmarks_for_cr,
                             vit_variants)


def test_model_registry():
    assert set(MODELS) == {"vit", "bert", "gpt2"}
    assert VIT.n == 65 and VIT.img == 32 and VIT.patch == 4
    assert BERT.vocab == 256 and not BERT.causal
    assert GPT2.causal and GPT2.kind == "decoder"
    assert VIT.dh * VIT.heads == VIT.d
    assert VIT.ffn == 4 * VIT.d


def test_variant_keys_are_unique():
    keys = [v.key() for v in all_variants()]
    assert len(keys) == len(set(keys))


def test_vit_variants_cover_table4_rows():
    vs = vit_variants()
    assert Variant("vit", "single") in vs
    assert Variant("vit", "voltage", 2) in vs
    assert Variant("vit", "voltage", 3) in vs
    prism = [v for v in vs if v.mode == "prism"]
    assert {(v.p, v.l) for v in prism} == {(2, 3), (2, 6), (2, 10),
                                           (3, 3), (3, 5), (3, 10)}


def test_bert_variants_include_max_compression():
    vs = bert_variants()
    assert Variant("bert", "prism", 2, 1) in vs  # PDPLC = 1 (paper CR=128)
    assert Variant("bert", "prism", 3, 1) in vs


def test_gpt2_variants_dedupe_equal_geometry():
    vs = [v for v in gpt2_variants() if v.mode == "prism"]
    assert len({(v.p, v.l) for v in vs}) == len(vs)
    # Eq. 16: P=2 CR=2 -> L=32; P=3 CR=10 -> L=4
    assert Variant("gpt2", "prism", 2, 32) in vs
    assert Variant("gpt2", "prism", 3, 4) in vs


def test_variant_key_format():
    assert Variant("vit", "single").key() == "vit_single"
    assert Variant("vit", "voltage", 3).key() == "vit_voltage_p3"
    assert Variant("gpt2", "prism", 2, 16).key() == "gpt2_prism_p2l16"


def test_cr_round_trip():
    for p in (2, 3):
        for cr in range(2, 11):
            l = landmarks_for_cr(GPT2.n, p, cr)
            eff = effective_cr(GPT2.n, p, l)
            # floor in Eq. 16 => effective CR >= nominal
            assert eff >= cr - 1e-9
    assert Variant("vit", "prism", 2, 6).cr() == pytest.approx(65 / 12)
    assert Variant("vit", "single").cr() is None
