"""Partition / segment-plan geometry (paper Algorithm 1, 2; Eq. 16, 17)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import (effective_cr, landmarks_for_cr,
                             partition_sizes, pdplc_prism, pdplc_voltage,
                             segment_counts)
from compile.plan import NEG_INF, PartitionPlan, plans, single_plan


@given(st.integers(2, 512), st.integers(1, 8))
def test_partition_sizes_cover_sequence(n, p):
    if n < p:
        return
    sizes = partition_sizes(n, p)
    assert len(sizes) == p
    assert sum(sizes) == n
    # Algorithm 1: all but the last are floor(N/P); last takes the remainder
    assert all(s == n // p for s in sizes[:-1])
    assert sizes[-1] == n // p + n % p


@given(st.integers(1, 300), st.integers(1, 32))
def test_segment_counts_cover_partition(n_p, l):
    if n_p < l:
        return
    counts = segment_counts(n_p, l)
    assert len(counts) == l
    assert sum(counts) == n_p
    assert all(c == n_p // l for c in counts[:-1])


def test_partition_rejects_invalid():
    with pytest.raises(ValueError):
        partition_sizes(3, 5)
    with pytest.raises(ValueError):
        segment_counts(2, 4)
    with pytest.raises(ValueError):
        partition_sizes(10, 0)


def test_eq16_landmarks():
    # paper: ViT N=197, P=2, CR=9.9 -> L = 9.9; PDPLC 10 tokens
    assert landmarks_for_cr(197, 2, 9.9) == 9
    assert landmarks_for_cr(128, 2, 2) == 32
    assert landmarks_for_cr(128, 3, 10) == 4
    assert landmarks_for_cr(16, 4, 100) == 1  # clamped to >= 1


def test_pdplc_matches_paper_convention():
    # Table IV: Voltage P=2 on N=197 -> 98 tokens/device/layer (paper: 99
    # with ceil); we follow floor(N/P) of Algorithm 1.
    assert pdplc_voltage(197, 2) == 98
    assert pdplc_prism(2, 10) == 10
    assert pdplc_prism(3, 10) == 20


@given(st.integers(8, 200), st.integers(2, 4), st.integers(1, 6),
       st.booleans())
@settings(max_examples=60)
def test_g_vector_sums_to_n(n, p, l, causal):
    if n // p < max(l, 1):
        return
    for pl in plans(n, p, l, causal):
        g = pl.g()
        assert g.shape == (pl.n_hat,)
        # local tokens count 1; peers' counts reconstruct their partitions
        assert int(g.sum()) == n
        assert np.all(g >= 1)


@given(st.integers(8, 200), st.integers(2, 4), st.integers(1, 6))
@settings(max_examples=60)
def test_causal_bias_no_future(n, p, l):
    """No column whose last covered token is in the future is visible."""
    if n // p < max(l, 1):
        return
    for pl in plans(n, p, l, True):
        b = pl.bias()
        cols = pl.col_positions()
        for i in range(pl.n_p):
            t = pl.start + i
            visible = b[i] > NEG_INF / 2
            assert np.array_equal(visible, cols <= t)


def test_causal_bias_matches_eq17_block_structure():
    """Eq. 17: all segment means of earlier partitions visible, later masked."""
    pls = plans(120, 3, 4, True)
    mid = pls[1]
    b = mid.bias()
    n_p = mid.n_p
    # local part: lower-triangular
    local = b[:, :n_p] > NEG_INF / 2
    assert np.array_equal(local, np.tril(np.ones((n_p, n_p), bool)))
    # earlier partition's L means: fully visible; later partition's: masked
    earlier = b[:, n_p:n_p + 4] > NEG_INF / 2
    later = b[:, n_p + 4:] > NEG_INF / 2
    assert earlier.all()
    assert not later.any()


def test_encoder_bias_is_log_g():
    pl = plans(65, 2, 3, False)[0]
    b = pl.bias()
    g = pl.g()
    assert np.allclose(b, np.log(g)[None, :].repeat(pl.n_p, 0))


def test_single_plan_causal_is_lower_triangular():
    pl = single_plan(16, True)
    vis = pl.bias() > NEG_INF / 2
    assert np.array_equal(vis, np.tril(np.ones((16, 16), bool)))
    assert np.allclose(single_plan(16, False).bias(), 0.0)


@given(st.integers(10, 120), st.integers(2, 3), st.integers(1, 5))
@settings(max_examples=40)
def test_effective_cr_and_ctx_len(n, p, l):
    if n // p < l:
        return
    cr = effective_cr(n, p, l)
    assert cr == pytest.approx(n / (l * p))
    for pl in plans(n, p, l, False):
        assert pl.ctx_len == (p - 1) * l
        assert pl.n_hat == pl.n_p + (p - 1) * l


def test_voltage_plan_ctx_is_rest_of_sequence():
    for pl in plans(100, 3, 0, False):
        assert pl.ctx_len == 100 - pl.n_p
        assert pl.n_hat == 100
        assert np.all(pl.g() == 1.0)


def test_bytes_per_exchange_helpers():
    from compile.plan import bytes_per_exchange, bytes_per_exchange_voltage
    # PRISM: (P-1) * L * D * 4 bytes; Voltage: (P-1) * floor(N/P) * D * 4
    assert bytes_per_exchange(128, 6, 2) == 1 * 6 * 128 * 4
    assert bytes_per_exchange(128, 6, 3) == 2 * 6 * 128 * 4
    assert bytes_per_exchange_voltage(65, 128, 2) == 32 * 128 * 4
    # PRISM always cheaper when L < floor(N/P)
    assert bytes_per_exchange(128, 6, 2) < \
        bytes_per_exchange_voltage(65, 128, 2)
