"""Synthetic dataset generators: determinism, balance, learnability hooks."""

import numpy as np

from compile import data as D
from compile.configs import BERT, GPT2


def test_vision_deterministic_and_shaped():
    x1, y1, xt1, yt1 = D.make_vision("synth10", 64, 32)
    x2, y2, xt2, yt2 = D.make_vision("synth10", 64, 32)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == (64, 32, 32, 3) and x1.dtype == np.float32
    assert y1.max() < 10


def test_vision_train_test_disjoint_noise():
    xtr, _, xte, _ = D.make_vision("synth10", 64, 64)
    assert not np.allclose(xtr[:16], xte[:16])


def test_vision_classes_distinguishable():
    """Class templates must differ far more than sample noise floor."""
    t = D._class_templates(10, "synth10")
    d = np.linalg.norm((t[0] - t[1]).ravel())
    assert d > 5.0


def test_vision_hard_is_noisier():
    a = D.make_vision("synth10", 32, 8)[0]
    b = D.make_vision("synthhard", 32, 8)[0]
    assert b.std() > a.std()


def test_glue_all_tasks_generate():
    for task in ("sst2p", "colap", "mrpcp", "qqpp", "rtep", "qnlip",
                 "mnlip", "stsbp"):
        x, y = D.make_glue(task, 96, "t")
        assert x.shape == (96, BERT.n) and x.dtype == np.int32
        assert x.max() < BERT.vocab and x.min() >= 0
        assert (x[:, 0] == D.CLS).all()
        if task == "mnlip":
            assert set(np.unique(y)) <= {0.0, 1.0, 2.0}
        elif task == "stsbp":
            assert 0.0 <= y.min() and y.max() <= 5.0
        else:
            assert set(np.unique(y)) <= {0.0, 1.0}


def test_glue_deterministic():
    a = D.make_glue("mnlip", 16, "x")[0]
    b = D.make_glue("mnlip", 16, "x")[0]
    np.testing.assert_array_equal(a, b)
    c = D.make_glue("mnlip", 16, "y")[0]
    assert not np.array_equal(a, c)


def test_glue_imbalance_targets():
    _, y_mrpc = D.make_glue("mrpcp", 1000, "bal")
    assert 0.55 < y_mrpc.mean() < 0.8  # positives dominate (like MRPC)
    _, y_qqp = D.make_glue("qqpp", 1000, "bal")
    assert 0.25 < y_qqp.mean() < 0.5


def test_corpus_charset_and_determinism():
    c1 = D.make_corpus(200)
    c2 = D.make_corpus(200)
    assert c1 == c2
    ids = D.encode_chars(c1)
    assert ids.min() >= 1 and ids.max() < GPT2.vocab
    assert c1.count(".") >= 200  # one per sentence


def test_lm_windows_shape():
    ids = D.encode_chars(D.make_corpus(500))
    w = D.lm_windows(ids, GPT2.n, 10, "t")
    assert w.shape == (10, GPT2.n + 1)
    # windows are corpus slices
    s = w[0]
    joined = "".join(
        {v: k for k, v in D.CHAR2ID.items()}[i] for i in s.tolist())
    assert joined in D.make_corpus(500)


def test_cloze_sets():
    for kind, vocab in (("cn", D._NOUNS), ("ne", D._NAMES)):
        cz = D.make_cloze(kind, 8)
        assert len(cz.answers) == 8
        for cands, ans in zip(cz.candidates, cz.answers):
            assert len(cands) == 10 and len(set(cands)) == 10
            assert cands[ans] in vocab


def test_cloze_truth_is_plausible():
    """The true candidate completes text drawn from the same grammar."""
    cz = D.make_cloze("cn", 4)
    for pre, suf, cands, ans in zip(cz.prefixes, cz.suffixes,
                                    cz.candidates, cz.answers):
        assert pre.endswith(" ")
        assert (pre + cands[ans] + suf).count(".") >= 2
