"""Unit tests for the AOT exporter's pure helpers (no lowering)."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, layers
from compile.configs import GPT2, VIT, Variant
from compile import model as M


def test_flatten_params_is_sorted_and_stable():
    params = {
        "embed": {"b": np.zeros(2), "a": np.ones(3)},
        "blocks": [{"w": np.zeros((2, 2))}, {"w": np.ones((2, 2))}],
    }
    flat = aot.flatten_params(params)
    names = [n for n, _ in flat]
    assert names == ["blocks.0.w", "blocks.1.w", "embed.a", "embed.b"]
    # idempotent
    assert [n for n, _ in aot.flatten_params(params)] == names


def test_write_weight_blob_offsets(tmp_path, monkeypatch):
    monkeypatch.setattr(aot, "ART", str(tmp_path))
    params = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
              "b": np.full((4,), 7.0, dtype=np.float32)}
    meta = aot.write_weight_blob("t", params)
    assert meta["elements"] == 10
    tensors = {t["name"]: t for t in meta["tensors"]}
    assert tensors["a"]["offset"] == 0 and tensors["a"]["shape"] == [2, 3]
    assert tensors["b"]["offset"] == 6
    raw = np.fromfile(tmp_path / "weights_t.bin", dtype="<f4")
    assert raw.tolist() == [0, 1, 2, 3, 4, 5, 7, 7, 7, 7]


def test_variant_record_fields():
    rec = aot.variant_record(VIT, Variant("vit", "prism", 2, 6))
    assert rec["cr"] == 65 / 12
    assert rec["pdplc"] == 6
    rec = aot.variant_record(VIT, Variant("vit", "voltage", 3))
    assert rec["pdplc"] == 2 * (65 // 3)
    rec = aot.variant_record(VIT, Variant("vit", "single"))
    assert "cr" not in rec


def test_block_fn_signature_and_outputs():
    fn, nw = aot.block_fn(VIT, "prism", 3, use_pallas=False)
    assert nw == len(layers.BLOCK_TENSORS)
    params = M.init_params(jax.random.PRNGKey(0), VIT, {"t": 2})
    blk = params["blocks"][0]
    w = [blk[n] for n, _ in layers.BLOCK_TENSORS]
    x = jnp.zeros((2, 32, VIT.d))
    ctx = jnp.zeros((2, 3, VIT.d))
    bias = jnp.zeros((32, 35))
    outs = fn(*w, x, ctx, bias)
    assert len(outs) == 2  # (x_out, z_out)
    assert outs[0].shape == (2, 32, VIT.d)
    assert outs[1].shape == (2, 3, VIT.d)

    fn_s, _ = aot.block_fn(GPT2, "single", 0, use_pallas=False)
    params = M.init_params(jax.random.PRNGKey(0), GPT2, {"lm": 5})
    w = [params["blocks"][0][n] for n, _ in layers.BLOCK_TENSORS]
    x = jnp.zeros((1, GPT2.n, GPT2.d))
    bias = jnp.zeros((GPT2.n, GPT2.n))
    outs = fn_s(*w, x, bias)
    assert len(outs) == 1


def test_embed_and_head_fns():
    fn, names = aot.embed_fn(VIT)
    assert names == [n for n, _ in layers.VIT_EMBED_TENSORS]
    params = M.init_params(jax.random.PRNGKey(0), VIT, {"t": 2})
    w = [params["embed"][n] for n in names]
    out = fn(*w, jnp.zeros((2, 32, 32, 3)))
    assert out[0].shape == (2, VIT.n, VIT.d)

    hfn, hnames = aot.head_fn(VIT, "cls")
    hw = [params["head_t"][n] for n in hnames]
    lg = hfn(*hw, jnp.zeros((2, VIT.n, VIT.d)))
    assert lg[0].shape == (2, 2)


def test_hlo_text_is_parseable_hlo():
    lowered = jax.jit(lambda a: (a * 2,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
