"""Layer-2 model invariants across the three inference modes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import BERT, GPT2, VIT, ModelConfig
from compile.plan import plans

TINY = ModelConfig(name="tiny", kind="encoder", n=24, d=32, heads=2,
                   layers=2)
TINYC = ModelConfig(name="tinyc", kind="decoder", n=24, d=32, heads=2,
                    layers=2, vocab=11, causal=True)


@pytest.fixture(scope="module")
def tiny_setup():
    params = M.init_params(jax.random.PRNGKey(0), TINY, {"t": 3})
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, TINY.n, TINY.d))
    return params, x


@pytest.fixture(scope="module")
def tinyc_setup():
    params = M.init_params(jax.random.PRNGKey(2), TINYC, {"lm": 11})
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(3), (2, TINYC.n, TINYC.d))
    return params, x


def test_voltage_equals_single_encoder(tiny_setup):
    """Position-wise partitioning is lossless (paper §II-B3)."""
    params, x = tiny_setup
    s = M.forward_single(params, TINY, x)
    for p in (2, 3, 4):
        v = M.forward_voltage(params, TINY, x, p)
        np.testing.assert_allclose(v, s, atol=2e-5, rtol=1e-4)


def test_voltage_equals_single_causal(tinyc_setup):
    params, x = tinyc_setup
    s = M.forward_single(params, TINYC, x)
    for p in (2, 3):
        v = M.forward_voltage(params, TINYC, x, p)
        np.testing.assert_allclose(v, s, atol=2e-5, rtol=1e-4)


def test_prism_equals_single_at_cr1(tiny_setup):
    """L = N_p (one token per segment) makes Segment Means the identity."""
    params, x = tiny_setup
    s = M.forward_single(params, TINY, x)
    for p in (2, 3):  # 24 divisible by both -> all partitions equal size
        pr = M.forward_prism(params, TINY, x, p, TINY.n // p)
        np.testing.assert_allclose(pr, s, atol=2e-5, rtol=1e-4)


def test_prism_equals_single_at_cr1_causal(tinyc_setup):
    params, x = tinyc_setup
    s = M.forward_single(params, TINYC, x)
    pr = M.forward_prism(params, TINYC, x, 2, TINYC.n // 2)
    np.testing.assert_allclose(pr, s, atol=2e-5, rtol=1e-4)


def test_prism_pallas_matches_ref_path(tiny_setup):
    params, x = tiny_setup
    a = M.forward_prism(params, TINY, x, 2, 3, use_pallas=False)
    b = M.forward_prism(params, TINY, x, 2, 3, use_pallas=True)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=1e-4)


def test_prism_compression_error_decreases_with_l(tiny_setup):
    """More landmarks (lower CR) => closer to the exact output."""
    params, x = tiny_setup
    s = M.forward_single(params, TINY, x)
    errs = []
    for l in (1, 3, 6, 12):
        pr = M.forward_prism(params, TINY, x, 2, l)
        errs.append(float(jnp.mean(jnp.abs(pr - s))))
    assert errs[-1] < errs[1] < errs[0] * 1.001
    assert errs[-1] < 1e-5  # L = N_p is exact


def test_prism_duplicated_flag_changes_output(tiny_setup):
    """Table II ablation: dropping the repetition counts changes attention."""
    params, x = tiny_setup
    a = M.forward_prism(params, TINY, x, 2, 3, duplicated=True)
    b = M.forward_prism(params, TINY, x, 2, 3, duplicated=False)
    assert float(jnp.max(jnp.abs(a - b))) > 1e-4


def test_causal_no_future_leak(tinyc_setup):
    """Perturbing a future token must not change earlier positions, in
    BOTH single and PRISM-distributed causal forward passes."""
    params, x = tinyc_setup
    t = 10
    x2 = x.at[:, t + 2, :].add(5.0)
    for fwd in (lambda z: M.forward_single(params, TINYC, z),
                lambda z: M.forward_prism(params, TINYC, z, 2, 4),
                lambda z: M.forward_voltage(params, TINYC, z, 3)):
        a, b = fwd(x), fwd(x2)
        np.testing.assert_allclose(a[:, :t + 2], b[:, :t + 2],
                                   atol=2e-5, rtol=1e-4)
        assert float(jnp.max(jnp.abs(a[:, t + 2:] - b[:, t + 2:]))) > 1e-3


def test_encoder_not_causal_by_default(tiny_setup):
    """Encoders see the whole sequence: early positions do change."""
    params, x = tiny_setup
    x2 = x.at[:, -1, :].add(100.0)
    a = M.forward_single(params, TINY, x)
    b = M.forward_single(params, TINY, x2)
    # the computation is deterministic, so ANY nonzero difference at
    # position 0 is genuine cross-token information flow (the untrained
    # residual stream attenuates it to ~1e-6); causal models are exactly
    # zero here (see test_causal_no_future_leak).
    assert float(jnp.max(jnp.abs(a[:, 0] - b[:, 0]))) > 1e-7


def test_block_apply_shapes():
    params = M.init_params(jax.random.PRNGKey(0), TINY, {"t": 3})
    pls = plans(TINY.n, 3, 2, False)
    pl = pls[1]
    x_p = jnp.zeros((4, pl.n_p, TINY.d))
    ctx = jnp.zeros((4, pl.ctx_len, TINY.d))
    bias = jnp.asarray(pl.bias())
    x, z = M.block_apply(params["blocks"][0], TINY, x_p, ctx, bias, l_out=2)
    assert x.shape == (4, pl.n_p, TINY.d)
    assert z.shape == (4, 2, TINY.d)


def test_embed_shapes_real_models():
    pv = M.init_params(jax.random.PRNGKey(0), VIT, {"synth10": 10})
    img = jnp.zeros((2, VIT.img, VIT.img, 3))
    assert M.embed(pv, VIT, img).shape == (2, VIT.n, VIT.d)

    pb = M.init_params(jax.random.PRNGKey(0), BERT, {"sst2p": 2})
    ids = jnp.zeros((2, BERT.n), jnp.int32)
    assert M.embed(pb, BERT, ids).shape == (2, BERT.n, BERT.d)

    pg = M.init_params(jax.random.PRNGKey(0), GPT2, {"lm": GPT2.vocab})
    ids = jnp.zeros((2, GPT2.n), jnp.int32)
    x = M.embed(pg, GPT2, ids)
    assert x.shape == (2, GPT2.n, GPT2.d)
    assert M.logits(pg, GPT2, x, "lm").shape == (2, GPT2.n, GPT2.vocab)


def test_cls_head_uses_token_zero(tiny_setup):
    params, x = tiny_setup
    lg1 = M.logits(params, TINY, x, "t")
    x2 = x.at[:, 5:, :].add(1.0)  # CLS untouched
    lg2 = M.logits(params, TINY, x2, "t")
    np.testing.assert_allclose(lg1, lg2, atol=1e-6)
    assert lg1.shape == (2, 3)
