"""Layer-2 model: the PRISM Transformer in its three inference modes.

``block_apply`` is the unit that gets AOT-compiled (one invocation per
layer per device). The ``forward_*`` functions chain blocks the way the
rust coordinator does at runtime — they exist for training, for tests, and
as executable documentation of the distributed protocol:

  single  : X -> block -> ... -> head                       (P = 1)
  voltage : devices exchange full partition outputs (AllGather) per block
  prism   : devices exchange Segment Means only; attention uses the
            scaling-aware softmax via an additive ``ln g`` bias

All three share identical weights; voltage == single exactly (position-wise
partitioning is lossless), prism == single exactly when L == N_p (CR = 1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .configs import ModelConfig, partition_sizes
from .kernels.prism_attention import prism_attention
from .kernels.ref import attention_ref, segment_means_ref
from .kernels.segment_means import segment_means as segment_means_pl
from .plan import PartitionPlan, plans, single_plan


def _split_heads(cfg: ModelConfig, x):
    b, n, _ = x.shape
    return x.reshape(b, n, cfg.heads, cfg.dh).transpose(0, 2, 1, 3)


def _merge_heads(cfg: ModelConfig, x):
    b, h, n, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * dh)


def block_apply(blk: dict, cfg: ModelConfig, x_p, ctx, bias, *,
                l_out: int = 0, use_pallas: bool = False):
    """One pre-LN Transformer block on one device.

    x_p:  (B, N_p, D) local partition.
    ctx:  (B, C, D) context rows appended to K/V — peers' segment means
          (prism), peers' full partitions (voltage), or None (single).
    bias: (N_p, N_p + C) additive attention bias = ln g + causal(-1e30).
    l_out: if > 0, also return the Segment Means of the block output
          (what this device transmits for the *next* layer).

    Returns (x_out, z_out) with z_out = None when l_out == 0.
    """
    n_p = x_p.shape[1]
    xhat = x_p if ctx is None else jnp.concatenate([x_p, ctx], axis=1)
    h = layers.ln1(blk, xhat)
    q = _split_heads(cfg, h[:, :n_p, :] @ blk["wq"] + blk["bq"])
    k = _split_heads(cfg, h @ blk["wk"] + blk["bk"])
    v = _split_heads(cfg, h @ blk["wv"] + blk["bv"])
    if use_pallas:
        attn = prism_attention(q, k, v, bias)
    else:
        attn = attention_ref(q, k, v, bias)
    x = x_p + _merge_heads(cfg, attn) @ blk["wo"] + blk["bo"]
    x = x + layers.ffn(blk, layers.ln2(blk, x))
    if l_out > 0:
        z = (segment_means_pl(x, l=l_out) if use_pallas
             else segment_means_ref(x, l_out))
        return x, z
    return x, None


def _zero_bias(plan: PartitionPlan) -> jnp.ndarray:
    return jnp.asarray(plan.bias())


def forward_single(params: dict, cfg: ModelConfig, x, *,
                   use_pallas: bool = False):
    """P=1 reference stack over embedded input x: (B, N, D) -> (B, N, D)."""
    bias = jnp.asarray(single_plan(cfg.n, cfg.causal).bias())
    for blk in params["blocks"]:
        x, _ = block_apply(blk, cfg, x, None, bias, use_pallas=use_pallas)
    return x


def forward_voltage(params: dict, cfg: ModelConfig, x, p: int, *,
                    use_pallas: bool = False):
    """Voltage [20] baseline: full AllGather of partition outputs per block.

    Simulates the P-device protocol in-process; output is the re-assembled
    (B, N, D) sequence. Exactly equals ``forward_single`` — position-wise
    partitioning is lossless; only communication differs.
    """
    pls = plans(cfg.n, p, 0, cfg.causal)
    parts = _partition(x, pls)
    biases = [jnp.asarray(pl.bias()) for pl in pls]
    for blk in params["blocks"]:
        outs = []
        for pl, xp in zip(pls, parts):
            ctx = jnp.concatenate([parts[j] for j in pl.peers], axis=1)
            out, _ = block_apply(blk, cfg, xp, ctx, biases[pl.p],
                                 use_pallas=use_pallas)
            outs.append(out)
        parts = outs  # the AllGather
    return jnp.concatenate(parts, axis=1)


def forward_prism(params: dict, cfg: ModelConfig, x, p: int, l: int, *,
                  use_pallas: bool = False, duplicated: bool = True):
    """PRISM distributed forward (in-process simulation of the protocol).

    Per block: each device attends over [X_p ; Z_peers] with the scaling-
    aware bias, then computes the Segment Means of its output and
    "transmits" them (here: collects into a list) for the next block.

    duplicated=False ablates Table II's "No duplication" row: segment means
    are used without repetition counts (g = 1 for context columns).
    """
    pls = plans(cfg.n, p, l, cfg.causal)
    parts = _partition(x, pls)
    # Master computes the first exchange from the embedded input (Fig. 1).
    zs = [segment_means_ref(xp, l) for xp in parts]
    biases = []
    for pl in pls:
        b = pl.bias()
        if not duplicated:
            # keep the causal part, drop ln g (counts -> 1)
            import numpy as np
            b = np.where(b < -1e29, b, 0.0).astype(np.float32)
        biases.append(jnp.asarray(b))
    for blk in params["blocks"]:
        outs, zouts = [], []
        for pl, xp in zip(pls, parts):
            ctx = jnp.concatenate([zs[j] for j in pl.peers], axis=1)
            out, z = block_apply(blk, cfg, xp, ctx, biases[pl.p],
                                 l_out=l, use_pallas=use_pallas)
            outs.append(out)
            zouts.append(z)
        parts, zs = outs, zouts  # the Segment-Means exchange
    return jnp.concatenate(parts, axis=1)


def _partition(x, pls: list[PartitionPlan]):
    return [x[:, pl.start:pl.start + pl.n_p, :] for pl in pls]


def embed(params: dict, cfg: ModelConfig, raw):
    if cfg.img:
        return layers.embed_images(params["embed"], cfg, raw)
    return layers.embed_tokens(params["embed"], cfg, raw)


def logits(params: dict, cfg: ModelConfig, x, head: str):
    pool = "all" if cfg.causal else "cls"
    return layers.head_apply(params[f"head_{head}"], cfg, x, pool=pool)


def init_params(key, cfg: ModelConfig, heads: dict[str, int]) -> dict:
    """heads: name -> output classes (1 for regression / vocab for LM)."""
    k_e, k_b, k_h = jax.random.split(key, 3)
    params = {
        "embed": layers.init_embed(k_e, cfg),
        "blocks": [layers.init_block(k, cfg)
                   for k in jax.random.split(k_b, cfg.layers)],
    }
    for name, classes in heads.items():
        k_h, k = jax.random.split(k_h)
        params[f"head_{name}"] = layers.init_head(k, cfg, classes)
    return params
