"""Synthetic datasets standing in for the paper's corpora (see DESIGN.md).

Everything is deterministic given the seed constants below, self-contained,
and exercises the same task shapes / metrics as the paper:

  vision   : synth10 / synth100 / synthhard   (CIFAR-10 / CIFAR-100 /
             ImageNet-1K stand-ins) — class-conditioned low-res templates,
             random shift, additive noise.
  language : 8 GLUE-proxy sequence tasks over a shared token generator.
  charlm   : grammar-generated English-like corpus for the GPT-2 model —
             BPC/BPB held-out evaluation + CBT-style cloze sets (common
             nouns vs named entities).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .configs import BERT, GPT2, VIT

SEED = 20250710

def _seed_of(*parts) -> int:
    """Deterministic cross-process seed (python's hash() is randomized)."""
    import hashlib
    h = hashlib.md5(repr(parts).encode()).digest()
    return int.from_bytes(h[:4], "little")


# ---------------------------------------------------------------- vision --

VISION_SPECS = {
    # name: (classes, noise sigma, max shift, contrast jitter)
    "synth10": (10, 0.55, 3, 0.0),
    "synth100": (100, 0.55, 3, 0.0),
    "synthhard": (100, 0.85, 5, 0.35),
}


def _class_templates(classes: int, tag: str) -> np.ndarray:
    """Per-class 8x8x3 pattern, bilinearly upsampled to 32x32x3."""
    rng = np.random.default_rng(_seed_of(*(SEED, "vision", tag)))
    low = rng.normal(size=(classes, 8, 8, 3)).astype(np.float32)
    # bilinear 4x upsample
    t = np.repeat(np.repeat(low, 4, axis=1), 4, axis=2)
    k = np.array([0.25, 0.5, 0.25], dtype=np.float32)
    for ax in (1, 2):
        t = (np.take(t, np.clip(np.arange(32) - 1, 0, 31), axis=ax) * k[0]
             + t * k[1]
             + np.take(t, np.clip(np.arange(32) + 1, 0, 31), axis=ax) * k[2])
    return t


def make_vision(name: str, n_train: int = 4096, n_test: int = 512):
    """Returns (x_train, y_train, x_test, y_test); images in [-2, 2]-ish."""
    classes, sigma, shift, jitter = VISION_SPECS[name]
    tmpl = _class_templates(classes, name)
    rng = np.random.default_rng(_seed_of(*(SEED, "vsamp", name)))

    def sample(n, salt):
        r = np.random.default_rng(
            _seed_of(*(SEED, "vsamp", name, salt)))
        y = r.integers(0, classes, size=n)
        x = tmpl[y].copy()
        for i in range(n):
            dx, dy = r.integers(-shift, shift + 1, size=2)
            x[i] = np.roll(x[i], (dx, dy), axis=(0, 1))
        if jitter:
            x *= (1.0 + jitter * r.normal(size=(n, 1, 1, 1))).astype(
                np.float32)
        x += sigma * r.normal(size=x.shape).astype(np.float32)
        return x.astype(np.float32), y.astype(np.int32)

    xtr, ytr = sample(n_train, "train")
    xte, yte = sample(n_test, "test")
    return xtr, ytr, xte, yte


# -------------------------------------------------------------- language --

PAD, CLS, SEP = 0, 1, 2
_POS_WORDS = np.arange(3, 43)       # sst2p positive lexicon
_NEG_WORDS = np.arange(43, 83)      # sst2p negative lexicon
_CONTENT = np.arange(83, 233)       # content words for pair tasks
_FILLER = np.arange(233, 256)
_DET_CLASS = np.arange(43, 53)   # colap "determiners"
_NOUN_CLASS = np.arange(53, 73)  # colap "nouns"


def _pack(a: np.ndarray, b: np.ndarray | None) -> np.ndarray:
    """[CLS] a [SEP] b [SEP] pad  -> fixed length BERT.n."""
    seq = [CLS, *a.tolist(), SEP]
    if b is not None:
        seq += [*b.tolist(), SEP]
    seq = seq[: BERT.n]
    return np.asarray(seq + [PAD] * (BERT.n - len(seq)), dtype=np.int32)


def _rng(task: str, salt: str) -> np.random.Generator:
    return np.random.default_rng(_seed_of(*(SEED, "glue", task, salt)))


def _sample_content(r, lo=8, hi=24):
    return r.choice(_CONTENT, size=r.integers(lo, hi), replace=False)


def make_glue(task: str, n: int, salt: str):
    """Returns (ids (n, 64) int32, labels float32 (n,)).

    Labels are class indices for classification tasks and the 0..5 score
    for stsbp.
    """
    r = _rng(task, salt)
    xs, ys = [], []
    for _ in range(n):
        if task == "sst2p":
            npos, nneg = r.integers(1, 12, size=2)
            words = np.concatenate([r.choice(_POS_WORDS, npos),
                                    r.choice(_NEG_WORDS, nneg),
                                    r.choice(_FILLER, r.integers(2, 8))])
            r.shuffle(words)
            xs.append(_pack(words, None)); ys.append(float(npos > nneg))
        elif task == "colap":
            # "grammatical" = starts with a determiner-class token and ends
            # with a noun-class token (a simple acceptability rule)
            n_tok = int(r.integers(8, 24))
            seq = r.choice(_FILLER, size=n_tok)
            label = float(r.random() < 0.7)
            if label == 1.0:
                seq[0] = r.choice(_DET_CLASS)
                seq[-1] = r.choice(_NOUN_CLASS)
            else:
                if r.random() < 0.5:
                    seq[0] = r.choice(_NOUN_CLASS)  # wrong opener
                    seq[-1] = r.choice(_NOUN_CLASS)
                else:
                    seq[0] = r.choice(_DET_CLASS)
                    seq[-1] = r.choice(_DET_CLASS)  # wrong closer
            xs.append(_pack(seq, None))
            ys.append(label)
        elif task in ("mrpcp", "qqpp"):
            a = _sample_content(r)
            pos_rate = 0.67 if task == "mrpcp" else 0.37
            label = float(r.random() < pos_rate)
            if label == 1.0:
                b = a.copy(); r.shuffle(b)
                drop = r.random(size=len(b)) < 0.15
                b = np.where(drop, r.choice(_CONTENT, len(b)), b)
            else:
                b = _sample_content(r)
            xs.append(_pack(a, b)); ys.append(label)
        elif task in ("rtep", "qnlip"):
            a = _sample_content(r, 10, 24)
            label = float(r.random() < 0.5)
            take = r.integers(3, max(4, len(a) // 2))
            b = (r.choice(a, take, replace=False) if label == 1.0
                 else r.choice(np.setdiff1d(_CONTENT, a), take))
            xs.append(_pack(a, b)); ys.append(label)
        elif task == "mnlip":
            a = _sample_content(r, 12, 24)
            cls3 = int(r.integers(0, 3))
            if cls3 == 0:      # entailment: b subset of a
                b = r.choice(a, r.integers(4, 8), replace=False)
            elif cls3 == 1:    # neutral: half overlap
                half = r.choice(a, 3, replace=False)
                rest = r.choice(np.setdiff1d(_CONTENT, a), 3)
                b = np.concatenate([half, rest])
            else:              # contradiction: disjoint
                b = r.choice(np.setdiff1d(_CONTENT, a), r.integers(4, 8))
            xs.append(_pack(a, b)); ys.append(float(cls3))
        elif task == "stsbp":
            a = _sample_content(r, 10, 20)
            keep = r.random()
            nkeep = int(round(keep * len(a)))
            b = np.concatenate([
                r.choice(a, nkeep, replace=False) if nkeep else
                np.empty(0, np.int64),
                r.choice(np.setdiff1d(_CONTENT, a), len(a) - nkeep)])
            r.shuffle(b)
            inter = len(np.intersect1d(a, b))
            union = len(np.union1d(a, b))
            xs.append(_pack(a, b)); ys.append(5.0 * inter / union)
        else:
            raise ValueError(task)
    return np.stack(xs), np.asarray(ys, dtype=np.float32)


# ---------------------------------------------------------------- charlm --

_NOUNS = ("river bridge garden stone castle forest valley market street "
          "harbor mountain meadow lantern window door table chair bottle "
          "letter book road cloud shadow tower wall farm mill barn field "
          "boat horse wagon bell rope basket candle mirror clock").split()
_NAMES = ("Alice Bruno Clara Dmitri Elena Farid Greta Henrik Ingrid Jonas "
          "Karim Lena Marko Nadia Oskar Petra Quentin Rosa Stefan Tara").split()
_VERBS = ("watches crosses builds paints guards opens closes carries finds "
          "follows leaves repairs draws sells buys remembers forgets "
          "visits").split()
_ADJS = ("old quiet bright narrow broken golden heavy silent green distant "
         "small wooden").split()
_ADVS = "slowly often quietly rarely carefully again".split()

CHARSET = sorted(set("abcdefghijklmnopqrstuvwxyz"
                     "ABCDEFGHIJKLMNOPQRSTUVWXYZ ., "))
CHAR2ID = {c: i + 1 for i, c in enumerate(CHARSET)}  # 0 = pad
assert len(CHAR2ID) + 1 <= GPT2.vocab


def _adj_nouns(adj: str) -> list[str]:
    """Each adjective licenses 3 nouns (deterministic): the statistical
    signal that makes the CBT-style common-noun cloze *learnable* — a
    char-LM can sharpen P(noun | adjective) far above the 10% floor."""
    r = np.random.default_rng(_seed_of(SEED, "adjmap", adj))
    return [str(x) for x in r.choice(_NOUNS, 3, replace=False)]


def _np(r, protagonist=None) -> str:
    if protagonist is not None:
        return protagonist
    adj = r.choice(_ADJS)
    return f"the {adj} {r.choice(_adj_nouns(adj))}"


def _sentence(r, protagonist=None) -> str:
    use_name = protagonist is not None and r.random() < 0.8
    subj = protagonist if use_name else _np(r)
    obj = _np(r)
    s = f"{subj} {r.choice(_VERBS)} {obj}"
    if r.random() < 0.3:
        s += f" {r.choice(_ADVS)}"
    return s + ". "


def _paragraph(r) -> str:
    """3-6 sentences sharing a protagonist name: cross-sentence signal
    for the named-entity cloze (the paper's CBT-NE proxy)."""
    hero = str(r.choice(_NAMES))
    return "".join(_sentence(r, hero)
                   for _ in range(int(r.integers(3, 7))))


def make_corpus(n_sentences: int = 24000) -> str:
    r = np.random.default_rng(_seed_of(*(SEED, "corpus")))
    out = []
    produced = 0
    while produced < n_sentences:
        para = _paragraph(r)
        produced += para.count(".")
        out.append(para)
    return "".join(out)


def encode_chars(text: str) -> np.ndarray:
    return np.asarray([CHAR2ID[c] for c in text], dtype=np.int32)


def lm_windows(ids: np.ndarray, n: int, count: int, salt: str) -> np.ndarray:
    r = np.random.default_rng(_seed_of(*(SEED, "lmwin", salt)))
    starts = r.integers(0, len(ids) - n - 1, size=count)
    return np.stack([ids[s:s + n + 1] for s in starts])  # (count, n+1)


@dataclasses.dataclass
class ClozeSet:
    """CBT-style cloze: predict the held-out word among 10 candidates."""
    prefixes: list[str]     # text up to and including the blank position
    suffixes: list[str]     # text after the candidate
    candidates: list[list[str]]  # 10 candidates, index 0 = truth shuffled in
    answers: list[int]      # index of the true candidate


def make_cloze(kind: str, n: int = 64) -> ClozeSet:
    """kind = "cn" (common nouns) or "ne" (named entities)."""
    r = np.random.default_rng(_seed_of(*(SEED, "cloze", kind)))
    prefixes, suffixes, cands, answers = [], [], [], []
    for _ in range(n):
        if kind == "cn":
            # the adjective licenses the noun: distractors are nouns the
            # adjective never co-occurs with in the corpus.
            hero = str(r.choice(_NAMES))
            ctx = "".join(_sentence(r, hero) for _ in range(3))
            adj = str(r.choice(_ADJS))
            allowed = _adj_nouns(adj)
            truth = str(r.choice(allowed))
            pre = ctx + f"{hero} {r.choice(_VERBS)} the {adj} "
            suf = "."
            pool = [w for w in _NOUNS if w not in allowed]
        else:
            # the paragraph's protagonist is the blanked subject.
            hero = str(r.choice(_NAMES))
            ctx = "".join(_sentence(r, hero) for _ in range(4))
            truth = hero
            pre = ctx
            suf = f" {r.choice(_VERBS)} {_np(r)}."
            pool = [w for w in _NAMES if w != truth]
        distract = list(r.choice(pool, 9, replace=False))
        cs = distract + [truth]
        r.shuffle(cs)
        prefixes.append(pre)
        suffixes.append(suf)
        cands.append([str(c) for c in cs])
        answers.append(cs.index(truth))
    return ClozeSet(prefixes, suffixes, cands, answers)
