"""Layer-1 Pallas kernel: Segment Means compression (paper Algorithm 2).

Reduces a partition's block output (B, N_p, D) to its L landmark vectors
(B, L, D): contiguous segments of s = N_p // L rows (the last segment takes
the remainder), each reduced by a column-wise mean.

TPU mapping: lane dimension = D (vector-register aligned), the per-segment
reduction is a strided-window sum over sublanes; segment boundaries are
static per AOT variant, so the loop fully unrolls — no dynamic shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _means_body(x_ref, z_ref, *, l: int, n_p: int):
    x = x_ref[0]  # (N_p, D)
    s, r = divmod(n_p, l)
    rows = []
    for i in range(l):  # static unroll: boundaries known at trace time
        lo = i * s
        hi = lo + s + (r if i == l - 1 else 0)
        rows.append(jnp.mean(x[lo:hi, :], axis=0))
    z_ref[0] = jnp.stack(rows, axis=0).astype(z_ref.dtype)


@functools.partial(jax.jit, static_argnames=("l", "interpret"))
def segment_means(x, *, l: int, interpret: bool = True):
    """x: (B, N_p, D) -> (B, L, D) segment means."""
    b, n_p, d = x.shape
    return pl.pallas_call(
        functools.partial(_means_body, l=l, n_p=n_p),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, n_p, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, l, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l, d), x.dtype),
        interpret=interpret,
    )(x)
