"""Pure-jnp oracles for the PRISM kernels.

Everything in this file is the *specification*: the Pallas kernels
(`prism_attention.py`, `segment_means.py`) and the rust-executed AOT
artifacts are tested against these functions. No pallas, no tricks — just
the paper's equations written plainly.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def segment_means_ref(x, l: int):
    """Algorithm 2: column-wise means of L contiguous segments.

    x: (..., N_p, D) -> (..., L, D). Segments 0..L-2 have s = N_p // L rows,
    the last has s + (N_p mod L).
    """
    n_p = x.shape[-2]
    s, r = divmod(n_p, l)
    means = []
    for i in range(l):
        lo = i * s
        hi = lo + s + (r if i == l - 1 else 0)
        means.append(jnp.mean(x[..., lo:hi, :], axis=-2))
    return jnp.stack(means, axis=-2)


def attention_ref(q, k, v, bias):
    """Vanilla biased attention: softmax(q kᵀ / sqrt(dh) + bias) v.

    q: (..., Nq, dh), k/v: (..., Nk, dh), bias: broadcastable to (Nq, Nk).
    With bias = ln g this *is* the scaling-aware softmax of Eq. 13–15:
    softmax(logits + ln g) == rownorm(exp(logits) ⊙ g).
    """
    dh = q.shape[-1]
    logits = jnp.einsum("...qd,...kd->...qk", q, k) / jnp.sqrt(
        jnp.asarray(dh, q.dtype))
    logits = logits + bias
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    return jnp.einsum("...qk,...kd->...qd", p, v) / jnp.sum(
        p, axis=-1, keepdims=True)


def prism_attention_scaled_ref(q, k_hat, v_hat, g, mask=None):
    """Eq. 13–15 exactly as written: Ψ = exp(logits); E = Ψ ⊙ g; A = S(E) V̂.

    ``g`` is the repetition-count vector over K̂/V̂ rows; ``mask`` (optional,
    1 = visible) is the partition-aware causal mask of Eq. 17. Numerically
    un-stabilized on purpose — it mirrors the paper's algebra; use small
    logits in tests.
    """
    dh = q.shape[-1]
    logits = jnp.einsum("...qd,...kd->...qk", q, k_hat) / jnp.sqrt(
        jnp.asarray(dh, q.dtype))
    psi = jnp.exp(logits)
    if mask is not None:
        psi = psi * mask
    e = psi * g  # column broadcast (Eq. 14)
    s = e / jnp.sum(e, axis=-1, keepdims=True)
    return jnp.einsum("...qk,...kd->...qd", s, v_hat)


def duplicated_attention_ref(q, k_hat, v_hat, counts, mask_rows=None):
    """Eq. 11/12: physically duplicate each K̂/V̂ row ``counts[j]`` times.

    The ground truth that the scaling-aware form must match. ``mask_rows``
    (optional, per original K̂ row, 1 = visible) is expanded alongside.
    """
    counts = np.asarray(counts, dtype=np.int64)
    idx = np.repeat(np.arange(len(counts)), counts)
    k_dup = jnp.take(k_hat, idx, axis=-2)
    v_dup = jnp.take(v_hat, idx, axis=-2)
    if mask_rows is None:
        bias = jnp.zeros((q.shape[-2], len(idx)), dtype=q.dtype)
    else:
        mrow = jnp.take(jnp.asarray(mask_rows), jnp.asarray(idx), axis=-1)
        bias = jnp.where(mrow > 0, 0.0, -1e30).astype(q.dtype)
    return attention_ref(q, k_dup, v_dup, bias)


def layernorm_ref(x, gamma, beta, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def gelu_ref(x):
    """tanh-approximated GELU (GPT-2 style)."""
    c = jnp.sqrt(jnp.asarray(2.0 / np.pi, x.dtype))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x ** 3)))
