"""Layer-1 Pallas kernel: PRISM scaling-aware attention.

Computes ``softmax(Q K̂ᵀ/√dh + bias) V̂`` where ``bias`` already folds the
paper's repetition vector (``ln g``, Eq. 13–15) and the partition-aware
causal mask (Eq. 17, as −1e30). A single fused pass per Q tile: row-max →
exp → row-sum → contraction with V̂.

TPU mapping (see DESIGN.md §Hardware-Adaptation): K̂/V̂ are *small* in PRISM
(N̂_p = N_p + (P−1)·L ≪ N) — that is the paper's point — so they stay fully
VMEM-resident while Q/output tiles stream via the grid. Both contractions
(Q·K̂ᵀ and S·V̂) hit the MXU. The repetition vector enters as an additive
bias row: no gathers, no physical duplication.

CPU note: ``interpret=True`` is mandatory on this image — real TPU lowering
emits Mosaic custom-calls the CPU PJRT plugin cannot execute. Interpret mode
lowers to plain HLO, so the AOT artifact runs anywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= cap (grid must cover Nq exactly)."""
    for t in range(min(cap, n), 0, -1):
        if n % t == 0:
            return t
    return n


def _attn_body(q_ref, k_ref, v_ref, b_ref, o_ref, *, scale: float):
    q = q_ref[0, 0]            # (bq, dh)
    k = k_ref[0, 0]            # (nk, dh) — VMEM-resident, shared over grid
    v = v_ref[0, 0]            # (nk, dh)
    bias = b_ref[...]          # (bq, nk)  = ln g + causal(-1e30)
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    logits = logits + bias
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    num = jnp.dot(p, v, preferred_element_type=jnp.float32)
    o_ref[0, 0] = (num / jnp.sum(p, axis=-1, keepdims=True)).astype(
        o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def prism_attention(q, k, v, bias, *, block_q: int = 32,
                    interpret: bool = True):
    """Fused PRISM attention.

    q: (B, H, Nq, dh); k, v: (B, H, Nk, dh); bias: (Nq, Nk) shared across
    batch and heads. Returns (B, H, Nq, dh).
    """
    b, h, nq, dh = q.shape
    nk = k.shape[-2]
    bq = _tile(nq, block_q)
    grid = (b, h, nq // bq)
    scale = 1.0 / (dh ** 0.5)
    return pl.pallas_call(
        functools.partial(_attn_body, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda i, j, t: (i, j, t, 0)),
            pl.BlockSpec((1, 1, nk, dh), lambda i, j, t: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, nk, dh), lambda i, j, t: (i, j, 0, 0)),
            pl.BlockSpec((bq, nk), lambda i, j, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda i, j, t: (i, j, t, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nq, dh), q.dtype),
        interpret=interpret,
    )(q, k, v, bias)


def vmem_footprint_bytes(nq: int, nk: int, dh: int, block_q: int = 32,
                         fp_bytes: int = 4) -> int:
    """Estimated VMEM working set per grid step (perf model for DESIGN.md).

    Q tile + resident K̂ + V̂ + bias tile + logits scratch + output tile.
    """
    bq = _tile(nq, block_q)
    return fp_bytes * (bq * dh + 2 * nk * dh + bq * nk + bq * nk + bq * dh)


def mxu_flops(nq: int, nk: int, dh: int) -> int:
    """MXU-eligible FLOPs (2×MAC) for one (batch, head) attention instance."""
    return 2 * nq * nk * dh * 2
