"""Partition / exchange planning: the request-independent geometry of PRISM.

For a given (N, P, L, causal) configuration this module derives, for every
partition index ``p``:

  * the local token span ``[start_p, start_p + N_p)`` in the global sequence,
  * the context layout — which peers' segment means are concatenated after
    the local tokens (global order, skipping ``p``),
  * the repetition vector ``g`` (Eq. 11/12's duplication counts),
  * the additive attention bias ``B[i, j] = ln g[j] + mask`` that folds the
    scaling-aware softmax (Eq. 13–15) and the partition-aware causal mask
    (Eq. 17) into a single tensor.

The rust coordinator re-implements this in ``rust/src/coordinator/plan.rs``;
fixtures exported by ``aot.py`` keep the two in lock-step.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .configs import partition_sizes, segment_counts

# Large negative bias standing in for -inf: exp(-1e30) == 0.0 in f32 without
# producing NaNs via (-inf) - (-inf) in the row-max subtraction.
NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Geometry for one device's view of one (N, P, L) configuration."""

    p: int                    # this device's partition index (0-based)
    n: int                    # global sequence length
    sizes: list[int]          # all partition sizes (Algorithm 1)
    l: int                    # landmarks per partition (0 => voltage/single)
    causal: bool

    @property
    def n_p(self) -> int:
        return self.sizes[self.p]

    @property
    def start(self) -> int:
        return sum(self.sizes[: self.p])

    @property
    def peers(self) -> list[int]:
        """Peer partition indices in global order (the Z_cat layout)."""
        return [j for j in range(len(self.sizes)) if j != self.p]

    @property
    def ctx_len(self) -> int:
        """Rows of context concatenated after the local partition."""
        if self.l == 0:  # voltage: full peer partitions
            return self.n - self.n_p
        return self.l * (len(self.sizes) - 1)

    @property
    def n_hat(self) -> int:
        return self.n_p + self.ctx_len

    def g(self) -> np.ndarray:
        """Repetition vector over the N_hat columns of K_hat/V_hat.

        Local tokens and voltage context rows count once; each peer segment
        mean counts as many times as the tokens it summarizes (Eq. 11).
        """
        parts = [np.ones(self.n_p, dtype=np.float32)]
        for j in self.peers:
            if self.l == 0:
                parts.append(np.ones(self.sizes[j], dtype=np.float32))
            else:
                parts.append(np.asarray(segment_counts(self.sizes[j], self.l),
                                        dtype=np.float32))
        return np.concatenate(parts)

    def col_positions(self) -> np.ndarray:
        """Global position of the *last* token covered by each K/V column.

        Used by the causal mask: a query at global position ``t`` may attend
        to column ``j`` iff ``col_pos[j] <= t``. For a segment mean this is
        the position of the last token in the segment — a mean is visible
        only once every token it aggregates is in the past (Eq. 17 admits
        only whole earlier *partitions*, which this generalizes exactly: all
        of an earlier partition's segments end before any local token).
        """
        cols = [np.arange(self.start, self.start + self.n_p, dtype=np.int64)]
        for j in self.peers:
            base = sum(self.sizes[:j])
            if self.l == 0:
                cols.append(np.arange(base, base + self.sizes[j],
                                      dtype=np.int64))
            else:
                ends = np.cumsum(segment_counts(self.sizes[j], self.l)) - 1
                cols.append(base + ends.astype(np.int64))
        return np.concatenate(cols)

    def bias(self) -> np.ndarray:
        """Additive attention bias, shape (N_p, N_hat): ln g + causal mask."""
        b = np.broadcast_to(np.log(self.g())[None, :],
                            (self.n_p, self.n_hat)).copy()
        if self.causal:
            qpos = np.arange(self.start, self.start + self.n_p)[:, None]
            visible = self.col_positions()[None, :] <= qpos
            b = np.where(visible, b, np.float32(NEG_INF))
        return b.astype(np.float32)


def plans(n: int, p: int, l: int, causal: bool) -> list[PartitionPlan]:
    """One plan per device for an (N, P, L) configuration."""
    sizes = partition_sizes(n, p)
    return [PartitionPlan(i, n, sizes, l, causal) for i in range(p)]


def single_plan(n: int, causal: bool) -> PartitionPlan:
    """P=1 degenerate plan: no context, optional plain causal mask."""
    return PartitionPlan(0, n, [n], 0, causal)


def bytes_per_exchange(d: int, l: int, p: int, fp_bytes: int = 4) -> int:
    """Unicast payload bytes one device sends per layer: (P-1) * L * D."""
    return (p - 1) * l * d * fp_bytes


def bytes_per_exchange_voltage(n: int, d: int, p: int,
                               fp_bytes: int = 4) -> int:
    """Voltage baseline: (P-1) * floor(N/P) * D elements per device-layer."""
    return (p - 1) * (n // p) * d * fp_bytes
