"""Build-time training of the tiny models (python never runs at serve time).

Produces ``artifacts/weights/<tag>.npz`` for:

  vit_synth10 / vit_synth100 / vit_synthhard      — per-dataset ViT
  vit_<ds>_ft                                     — PRISM-finetuned ViT
                                                    (P=3, L=3; Table IV's
                                                    "PRISM (Finetuned)" row)
  bert                                            — multi-task GLUE-proxy
  gpt2                                            — char-level LM

Training is deliberately small (1 CPU core): a few hundred Adam steps each.
Absolute accuracies are recorded in EXPERIMENTS.md; the paper comparison is
about *relative* degradation vs. compression rate.
"""

from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import model as M
from .configs import BERT, GPT2, VIT, BERT_TASKS, VIT_DATASETS

WEIGHTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..",
                           "artifacts", "weights")


# ------------------------------------------------------------------ adam --

def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"],
                     grads)
    mh = jax.tree.map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree.map(lambda v: v / (1 - b2 ** t), v)
    params = jax.tree.map(lambda p, m, v: p - lr * m / (jnp.sqrt(v) + eps),
                          params, mh, vh)
    return params, {"m": m, "v": v, "t": t}


def ce_loss(lg, y):
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, y[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


# ----------------------------------------------------------- npz helpers --

def save_params(tag: str, params: dict) -> str:
    os.makedirs(WEIGHTS_DIR, exist_ok=True)
    flat = {}

    def walk(prefix, obj):
        if isinstance(obj, dict):
            for k, v in obj.items():
                walk(f"{prefix}.{k}" if prefix else k, v)
        elif isinstance(obj, list):
            for i, v in enumerate(obj):
                walk(f"{prefix}.{i}", v)
        else:
            flat[prefix] = np.asarray(obj)

    walk("", params)
    path = os.path.join(WEIGHTS_DIR, f"{tag}.npz")
    np.savez(path, **flat)
    return path


def load_params(tag: str) -> dict:
    path = os.path.join(WEIGHTS_DIR, f"{tag}.npz")
    z = np.load(path)
    params: dict = {}
    for key in z.files:
        parts = key.split(".")
        cur = params
        for i, part in enumerate(parts[:-1]):
            nxt = parts[i + 1]
            default = [] if nxt.isdigit() else {}
            if part.isdigit():
                idx = int(part)
                while len(cur) <= idx:
                    cur.append({} if not isinstance(default, list) else [])
                if not cur[idx]:
                    cur[idx] = default
                cur = cur[idx]
            else:
                cur = cur.setdefault(part, default)
        last = parts[-1]
        arr = jnp.asarray(z[key])
        if last.isdigit():
            idx = int(last)
            while len(cur) <= idx:
                cur.append(None)
            cur[idx] = arr
        else:
            cur[last] = arr
    return params


def have(tag: str) -> bool:
    return os.path.exists(os.path.join(WEIGHTS_DIR, f"{tag}.npz"))


# -------------------------------------------------------------- training --

def _batches(n, bs, rng):
    idx = rng.permutation(n)
    for i in range(0, n - bs + 1, bs):
        yield idx[i:i + bs]


def train_vit(ds: str, steps: int = 300, bs: int = 32, lr: float = 1e-3,
              log=print):
    classes = VIT_DATASETS[ds]
    xtr, ytr, xte, yte = D.make_vision(ds)
    params = M.init_params(jax.random.PRNGKey(0), VIT, {ds: classes})

    def loss_fn(p, xb, yb):
        x = M.embed(p, VIT, xb)
        x = M.forward_single(p, VIT, x)
        return ce_loss(M.logits(p, VIT, x, ds), yb)

    step = jax.jit(lambda p, s, xb, yb: _sgd_step(p, s, xb, yb, loss_fn, lr))
    state = adam_init(params)
    rng = np.random.default_rng(0)
    t0, i = time.time(), 0
    while i < steps:
        for bidx in _batches(len(xtr), bs, rng):
            params, state, lv = step(params, state, xtr[bidx], ytr[bidx])
            i += 1
            if i % 100 == 0:
                log(f"  [{ds}] step {i} loss {float(lv):.4f} "
                    f"({time.time() - t0:.0f}s)")
            if i >= steps:
                break
    acc = eval_vit(params, ds, xte, yte)
    log(f"  [{ds}] test acc {acc:.4f}")
    return params, acc


def _sgd_step(p, s, xb, yb, loss_fn, lr):
    lv, g = jax.value_and_grad(loss_fn)(p, xb, yb)
    p, s = adam_update(p, g, s, lr)
    return p, s, lv


def eval_vit(params, ds, xte, yte, mode="single", p=1, l=0) -> float:
    @jax.jit
    def fwd(xb):
        x = M.embed(params, VIT, xb)
        if mode == "single":
            x = M.forward_single(params, VIT, x)
        elif mode == "voltage":
            x = M.forward_voltage(params, VIT, x, p)
        else:
            x = M.forward_prism(params, VIT, x, p, l)
        return jnp.argmax(M.logits(params, VIT, x, ds), -1)

    hits = 0
    for i in range(0, len(xte), 64):
        hits += int(jnp.sum(fwd(xte[i:i + 64]) == yte[i:i + 64]))
    return hits / len(xte)


def finetune_vit_prism(params, ds: str, p: int, l: int, steps: int = 120,
                       bs: int = 32, lr: float = 3e-4, log=print):
    """Fine-tune with the PRISM forward in the loop (Table IV last row)."""
    xtr, ytr, _, _ = D.make_vision(ds)

    def loss_fn(pp, xb, yb):
        x = M.embed(pp, VIT, xb)
        x = M.forward_prism(pp, VIT, x, p, l)
        return ce_loss(M.logits(pp, VIT, x, ds), yb)

    step = jax.jit(lambda pp, s, xb, yb: _sgd_step(pp, s, xb, yb, loss_fn,
                                                   lr))
    state = adam_init(params)
    rng = np.random.default_rng(1)
    i = 0
    while i < steps:
        for bidx in _batches(len(xtr), bs, rng):
            params, state, lv = step(params, state, xtr[bidx], ytr[bidx])
            i += 1
            if i >= steps:
                break
    log(f"  [{ds}] finetune(p={p},l={l}) done loss {float(lv):.4f}")
    return params


def train_bert(steps: int = 2800, bs: int = 32, lr: float = 1e-3, log=print):
    heads = {t: (c if c > 1 else 1) for t, (c, _) in BERT_TASKS.items()}
    params = M.init_params(jax.random.PRNGKey(1), BERT, heads)
    train_sets = {t: D.make_glue(t, 2048, "train") for t in BERT_TASKS}

    def loss_fn(p, task, xb, yb):
        x = M.embed(p, BERT, xb)
        x = M.forward_single(p, BERT, x)
        lg = M.logits(p, BERT, x, task)
        if BERT_TASKS[task][0] == 1:  # regression
            return jnp.mean((lg[:, 0] - yb) ** 2) * 0.5
        return ce_loss(lg, yb.astype(jnp.int32))

    steps_fns = {t: jax.jit(
        lambda p, s, xb, yb, _t=t: _sgd_step(p, s, xb, yb,
                                             lambda pp, a, b: loss_fn(
                                                 pp, _t, a, b), lr))
        for t in BERT_TASKS}
    state = adam_init(params)
    rng = np.random.default_rng(2)
    tasks = list(BERT_TASKS)
    t0 = time.time()
    for i in range(steps):
        task = tasks[i % len(tasks)]
        xs, ys = train_sets[task]
        bidx = rng.integers(0, len(xs), bs)
        params, state, lv = steps_fns[task](params, state, xs[bidx],
                                            ys[bidx])
        if (i + 1) % 100 == 0:
            log(f"  [bert/{task}] step {i + 1} loss {float(lv):.4f} "
                f"({time.time() - t0:.0f}s)")
    return params


def train_gpt2(steps: int = 700, bs: int = 16, lr: float = 1e-3, log=print):
    corpus = D.make_corpus()
    ids = D.encode_chars(corpus)
    split = int(0.9 * len(ids))
    train_ids = ids[:split]
    params = M.init_params(jax.random.PRNGKey(2), GPT2, {"lm": GPT2.vocab})

    def loss_fn(p, wb):
        x = M.embed(p, GPT2, wb[:, :-1])
        x = M.forward_single(p, GPT2, x)
        lg = M.logits(p, GPT2, x, "lm")
        return ce_loss(lg.reshape(-1, GPT2.vocab), wb[:, 1:].reshape(-1))

    step = jax.jit(lambda p, s, wb: _sgd_step(
        p, s, wb, None, lambda pp, a, _b: loss_fn(pp, a), lr))
    state = adam_init(params)
    rng = np.random.default_rng(3)
    t0 = time.time()
    for i in range(steps):
        starts = rng.integers(0, len(train_ids) - GPT2.n - 1, bs)
        wb = np.stack([train_ids[s:s + GPT2.n + 1] for s in starts])
        params, state, lv = step(params, state, wb)
        if (i + 1) % 100 == 0:
            bpc = float(lv) / np.log(2)
            log(f"  [gpt2] step {i + 1} loss {float(lv):.4f} "
                f"(~{bpc:.3f} bpc) ({time.time() - t0:.0f}s)")
    return params


def _sgd_step3(p, s, a, b, loss_fn, lr):  # pragma: no cover - alias
    return _sgd_step(p, s, a, b, loss_fn, lr)


def main(force: bool = False, log=print):
    jobs = []
    for ds in VIT_DATASETS:
        jobs.append((f"vit_{ds}", lambda ds=ds: train_vit(ds, log=log)[0]))
    jobs.append(("bert", lambda: train_bert(log=log)))
    jobs.append(("gpt2", lambda: train_gpt2(log=log)))
    trained = {}
    for tag, fn in jobs:
        if have(tag) and not force:
            log(f"[train] {tag}: cached")
            continue
        log(f"[train] {tag} ...")
        params = fn()
        save_params(tag, params)
        trained[tag] = params
    # PRISM finetuning needs the base ViT weights.
    for ds in VIT_DATASETS:
        tag = f"vit_{ds}_ft"
        if have(tag) and not force:
            log(f"[train] {tag}: cached")
            continue
        base = trained.get(f"vit_{ds}") or load_params(f"vit_{ds}")
        log(f"[train] {tag} ...")
        ft = finetune_vit_prism(base, ds, p=3, l=3, log=log)
        save_params(tag, ft)


if __name__ == "__main__":
    main(force="--force" in sys.argv)
