"""AOT export: lower every executable variant to HLO text + manifests.

This is the only bridge between python and rust. It produces, under
``artifacts/``:

  manifest.json          — the full python→rust contract: model configs,
                           weight-blob layouts, executable inventory
                           (inputs/outputs/shapes), experiment variants
                           (CR/PDPLC bookkeeping), dataset registry.
  <model>/*.hlo.txt      — HLO text per executable (text, NOT serialized
                           proto: xla_extension 0.5.1 rejects jax>=0.5's
                           64-bit instruction ids; text re-assigns ids).
  weights_<tag>.bin      — flat little-endian f32 blobs.
  data/<name>/*          — exported evaluation datasets.
  fixtures/*             — input/output pairs for rust parity tests.

Executable flavors: ``xla`` lowers the block with the pure-jnp attention
(XLA fuses it; fastest on this 1-core CPU target) and ``pallas`` with the
Layer-1 Pallas kernels under interpret=True (the TPU hot-path expression;
~4.6x slower on CPU because interpret mode emulates the grid). Both flavors
are bit-compared against the same oracle; accuracy sweeps default to xla,
kernel-proof paths and examples to pallas. See DESIGN.md.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import layers, model as M, train as T
from .configs import (BERT, BERT_TASKS, EVAL_B, GPT2, LAT_B, MODELS, VIT,
                      VIT_DATASETS, Variant, all_variants, effective_cr,
                      partition_sizes, pdplc_prism, pdplc_voltage,
                      vit_variants)
from .plan import PartitionPlan, plans, single_plan

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), "..", ".."))
ART = os.path.join(ROOT, "artifacts")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


# -------------------------------------------------------------- weights ---

def flatten_params(params: dict) -> list[tuple[str, np.ndarray]]:
    out: list[tuple[str, np.ndarray]] = []

    def walk(prefix, obj):
        if isinstance(obj, dict):
            for k in sorted(obj):
                walk(f"{prefix}.{k}" if prefix else k, obj[k])
        elif isinstance(obj, list):
            for i, v in enumerate(obj):
                walk(f"{prefix}.{i}", v)
        else:
            out.append((prefix, np.asarray(obj, dtype=np.float32)))

    walk("", params)
    return out


def write_weight_blob(tag: str, params: dict) -> dict:
    tensors = flatten_params(params)
    path = os.path.join(ART, f"weights_{tag}.bin")
    meta, off = [], 0
    with open(path, "wb") as f:
        for name, arr in tensors:
            f.write(arr.astype("<f4").tobytes())
            meta.append({"name": name, "shape": list(arr.shape),
                         "offset": off})
            off += arr.size
    return {"file": f"weights_{tag}.bin", "elements": off, "tensors": meta}


# ---------------------------------------------------------- executables ---

class Exporter:
    def __init__(self):
        self.entries: list[dict] = []
        self.t0 = time.time()

    def lower(self, model: str, name: str, fn, arg_specs, meta: dict,
              log=print):
        """jit-lower fn(*args) with ShapeDtypeStructs and write HLO text."""
        os.makedirs(os.path.join(ART, model), exist_ok=True)
        np_dt = {"f32": np.float32, "i32": np.int32}
        specs = [jax.ShapeDtypeStruct(s, np_dt[d]) for s, d in arg_specs]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        rel = f"{model}/{name}.hlo.txt"
        with open(os.path.join(ART, rel), "w") as f:
            f.write(text)
        out_avals = lowered.out_info
        outs = [{"shape": list(o.shape), "dtype": _dt(o.dtype)}
                for o in jax.tree.leaves(out_avals)]
        entry = dict(meta)
        entry.update({"name": name, "file": rel, "outputs": outs})
        self.entries.append(entry)
        log(f"[aot] {rel} ({len(text) / 1024:.0f} KiB, "
            f"{time.time() - self.t0:.0f}s)")
        return entry


def _dt(dtype) -> str:
    return {"float32": "f32", "int32": "i32"}[np.dtype(dtype).name]


def block_fn(cfg, mode: str, l: int, use_pallas: bool):
    """Returns (fn, n_weight_inputs) for one block executable."""
    names = [n for n, _ in layers.BLOCK_TENSORS]

    def fn(*args):
        w = dict(zip(names, args[:len(names)]))
        rest = args[len(names):]
        if mode == "single":
            x_p, bias = rest
            ctx = None
        else:
            x_p, ctx, bias = rest
        x, z = M.block_apply(w, cfg, x_p, ctx, bias,
                             l_out=(l if mode == "prism" else 0),
                             use_pallas=use_pallas)
        return (x, z) if mode == "prism" else (x,)

    return fn, len(names)


def embed_fn(cfg):
    ts = layers.embed_tensors(cfg)
    names = [n for n, _ in ts]

    def fn(*args):
        w = dict(zip(names, args[:len(names)]))
        raw = args[len(names)]
        return (M.embed({"embed": w}, cfg, raw),)

    return fn, names


def head_fn(cfg, pool: str):
    names = [n for n, _ in layers.HEAD_TENSORS]

    def fn(*args):
        w = dict(zip(names, args[:len(names)]))
        x = args[len(names)]
        return (layers.head_apply(w, cfg, x, pool=pool),)

    return fn, names


def weight_specs(cfg, tensors, classes=None):
    return [(fn(cfg) if classes is None else fn(cfg, classes), "f32")
            for _, fn in tensors]


def export_block(ex: Exporter, cfg, var: Variant, part: int, batch: int,
                 flavor: str, log):
    mode, l = var.mode, var.l
    if mode == "single":
        pl = single_plan(cfg.n, cfg.causal)
    else:
        pl = plans(cfg.n, var.p, l if mode == "prism" else 0,
                   cfg.causal)[part]
    fn, nw = block_fn(cfg, mode, l, flavor == "pallas")
    specs = weight_specs(cfg, layers.BLOCK_TENSORS)
    specs.append(((batch, pl.n_p, cfg.d), "f32"))              # x_p
    if mode != "single":
        specs.append(((batch, pl.ctx_len, cfg.d), "f32"))       # ctx
    specs.append(((pl.n_p, pl.n_hat), "f32"))                   # bias
    name = f"{var.key()}_part{part}_b{batch}_{flavor}"
    args = [{"name": "x_p", "shape": [batch, pl.n_p, cfg.d], "dtype": "f32"}]
    if mode != "single":
        args.append({"name": "ctx", "shape": [batch, pl.ctx_len, cfg.d],
                     "dtype": "f32"})
    args.append({"name": "bias", "shape": [pl.n_p, pl.n_hat],
                 "dtype": "f32"})
    ex.lower(cfg.name, name, fn, specs, {
        "kind": "block", "model": cfg.name, "mode": mode, "p": var.p,
        "l": l, "part": part, "batch": batch, "flavor": flavor,
        "weight_inputs": [f"blocks.{{layer}}.{n}"
                          for n, _ in layers.BLOCK_TENSORS],
        "args": args,
    }, log)


def export_model(ex: Exporter, cfg, variants, batches, log):
    # embed + heads per batch size
    raw_spec = ((None, cfg.img, cfg.img, 3), "f32") if cfg.img else \
        ((None, cfg.n), "i32")
    heads = (VIT_DATASETS if cfg.name == "vit"
             else {t: c for t, (c, _) in BERT_TASKS.items()}
             if cfg.name == "bert" else {"lm": cfg.vocab})
    for b in batches:
        fn, names = embed_fn(cfg)
        shape = (b, cfg.img, cfg.img, 3) if cfg.img else (b, cfg.n)
        dtype = "f32" if cfg.img else "i32"
        specs = weight_specs(cfg, layers.embed_tensors(cfg))
        specs.append((shape, dtype))
        ex.lower(cfg.name, f"{cfg.name}_embed_b{b}", fn, specs, {
            "kind": "embed", "model": cfg.name, "batch": b,
            "mode": "", "p": 0, "l": 0, "part": 0, "flavor": "xla",
            "weight_inputs": [f"embed.{n}" for n in names],
            "args": [{"name": "raw", "shape": list(shape), "dtype": dtype}],
        }, log)
        for task, classes in heads.items():
            classes = classes if classes > 1 else 1
            fn, names = head_fn(cfg, "all" if cfg.causal else "cls")
            specs = weight_specs(cfg, layers.HEAD_TENSORS, classes)
            specs.append(((b, cfg.n, cfg.d), "f32"))
            ex.lower(cfg.name, f"{cfg.name}_head_{task}_b{b}", fn, specs, {
                "kind": "head", "model": cfg.name, "batch": b, "task": task,
                "classes": classes, "mode": "", "p": 0, "l": 0, "part": 0,
                "flavor": "xla",
                "weight_inputs": [f"head_{task}.{n}" for n in names],
                "args": [{"name": "x", "shape": [b, cfg.n, cfg.d],
                          "dtype": "f32"}],
            }, log)
    # blocks
    for var in variants:
        parts = 1 if var.mode == "single" else var.p
        flavors = ["xla"]
        # pallas flavor: the headline ViT model everywhere; one gpt2 config
        # (used by the generation example / kernel-proof tests).
        if cfg.name == "vit" or (cfg.name == "gpt2" and var.mode == "prism"
                                 and var.p == 2 and var.l == 16):
            flavors.append("pallas")
        for b in batches:
            for part in range(parts):
                for flavor in flavors:
                    export_block(ex, cfg, var, part, b, flavor, log)


# ------------------------------------------------------------- datasets ---

def export_datasets(log):
    dd = os.path.join(ART, "data")
    os.makedirs(dd, exist_ok=True)

    def write(name, arrays, meta):
        d = os.path.join(dd, name)
        os.makedirs(d, exist_ok=True)
        for fname, arr in arrays.items():
            arr.tofile(os.path.join(d, fname))
        meta["count"] = int(next(iter(arrays.values())).shape[0])
        with open(os.path.join(d, "meta.json"), "w") as f:
            json.dump(meta, f, indent=1)
        log(f"[data] {name}: {meta}")

    for ds in VIT_DATASETS:
        _, _, xte, yte = D.make_vision(ds)
        write(ds, {"x.bin": xte.astype("<f4"), "y.bin": yte.astype("<i4")},
              {"kind": "vision", "model": "vit", "classes":
               VIT_DATASETS[ds], "shape": list(xte.shape[1:])})
    for task, (classes, metric) in BERT_TASKS.items():
        ids, ys = D.make_glue(task, 512, "test")
        write(task, {"x.bin": ids.astype("<i4"),
                     "y.bin": ys.astype("<f4")},
              {"kind": "glue", "model": "bert", "classes": classes,
               "metric": metric, "shape": [BERT.n]})
    # char-LM: held-out windows for BPC (lowercase view) and BPB (raw view)
    corpus = D.make_corpus()
    split = int(0.9 * len(corpus))
    held = corpus[split:]
    raw_ids = D.encode_chars(held)
    low_ids = D.encode_chars(held.lower())
    for name, ids in (("enwik8p", raw_ids), ("text8p", low_ids)):
        win = D.lm_windows(ids, GPT2.n, 128, name)
        write(name, {"x.bin": win.astype("<i4")},
              {"kind": "charlm", "model": "gpt2", "shape": [GPT2.n + 1]})
    # cloze sets
    for kind, name in (("cn", "cbtcn"), ("ne", "cbtne")):
        cz = D.make_cloze(kind, 64)
        rows, spans, answers = [], [], []
        for pre, suf, cands, ans in zip(cz.prefixes, cz.suffixes,
                                        cz.candidates, cz.answers):
            for c in cands:
                text = pre + c + suf
                ids = D.encode_chars(text)
                start = len(D.encode_chars(pre))
                end = start + len(D.encode_chars(c))
                # fit into N+1 window ending at the candidate end
                hi = min(len(ids), max(end, GPT2.n + 1))
                lo = hi - (GPT2.n + 1)
                if lo < 0:  # left-pad with corpus text to fill the window
                    pad = D.encode_chars(corpus[:(-lo)])
                    ids = np.concatenate([pad, ids]); lo, hi = 0, GPT2.n + 1
                    start += len(pad); end += len(pad)
                rows.append(ids[lo:hi + 1][:GPT2.n + 1])
                spans.append([start - lo, end - lo])
            answers.append(ans)
        write(name, {"x.bin": np.stack(rows).astype("<i4"),
                     "spans.bin": np.asarray(spans, "<i4"),
                     "y.bin": np.asarray(answers, "<i4")},
              {"kind": "cloze", "model": "gpt2", "candidates": 10,
               "shape": [GPT2.n + 1]})


# ------------------------------------------------------------- fixtures ---

def export_fixtures(weight_sets: dict, log):
    """Dump (inputs, expected outputs) for rust ↔ python parity tests."""
    fd = os.path.join(ART, "fixtures")
    os.makedirs(fd, exist_ok=True)
    rng = np.random.default_rng(7)
    fixtures = []

    def dump(tag, arrays):
        for i, a in enumerate(arrays):
            np.asarray(a).astype("<f4" if a.dtype.kind == "f"
                                 else "<i4").tofile(
                os.path.join(fd, f"{tag}_{i}.bin"))

    cases = [("vit", Variant("vit", "prism", 2, 6), 0, "xla"),
             ("vit", Variant("vit", "prism", 2, 6), 1, "pallas"),
             ("vit", Variant("vit", "voltage", 3), 1, "xla"),
             ("gpt2", Variant("gpt2", "prism", 3, 10), 1, "xla"),
             ("gpt2", Variant("gpt2", "single"), 0, "xla")]
    for mname, var, part, flavor in cases:
        cfg = MODELS[mname]
        if var.mode == "single":
            pl = single_plan(cfg.n, cfg.causal)
        else:
            pl = plans(cfg.n, var.p, var.l if var.mode == "prism" else 0,
                       cfg.causal)[part]
        params = weight_sets[("vit_synth10" if mname == "vit" else mname)]
        blk = params["blocks"][1]
        x_p = rng.normal(size=(EVAL_B, pl.n_p, cfg.d)).astype(np.float32)
        ctx = rng.normal(size=(EVAL_B, pl.ctx_len, cfg.d)).astype(np.float32)
        bias = pl.bias()
        x, z = M.block_apply(blk, cfg, jnp.asarray(x_p),
                             None if var.mode == "single"
                             else jnp.asarray(ctx), jnp.asarray(bias),
                             l_out=(var.l if var.mode == "prism" else 0),
                             use_pallas=(flavor == "pallas"))
        name = f"{var.key()}_part{part}_b{EVAL_B}_{flavor}"
        ins = [x_p] + ([] if var.mode == "single" else [ctx]) + [bias]
        outs = [np.asarray(x)] + ([np.asarray(z)] if z is not None else [])
        dump(f"{name}_in", ins)
        dump(f"{name}_out", outs)
        fixtures.append({
            "executable": name, "layer": 1,
            "weights": "vit_synth10" if mname == "vit" else mname,
            "inputs": [f"{name}_in_{i}.bin" for i in range(len(ins))],
            "expected": [f"{name}_out_{i}.bin" for i in range(len(outs))],
            "tolerance": 2e-4})
        log(f"[fixture] {name}")
    with open(os.path.join(fd, "fixtures.json"), "w") as f:
        json.dump(fixtures, f, indent=1)


# ----------------------------------------------------------------- main ---

def variant_record(cfg, var: Variant) -> dict:
    rec = {"key": var.key(), "model": var.model, "mode": var.mode,
           "p": var.p, "l": var.l}
    if var.mode == "prism":
        rec["cr"] = effective_cr(cfg.n, var.p, var.l)
        rec["pdplc"] = pdplc_prism(var.p, var.l)
    elif var.mode == "voltage":
        rec["cr"] = 1.0
        rec["pdplc"] = pdplc_voltage(cfg.n, var.p)
    return rec


def main(log=print):
    os.makedirs(ART, exist_ok=True)
    T.main(log=log)  # ensure weights exist (cached if already trained)

    weight_sets = {tag: T.load_params(tag) for tag in
                   [f"vit_{ds}" for ds in VIT_DATASETS] +
                   [f"vit_{ds}_ft" for ds in VIT_DATASETS] +
                   ["bert", "gpt2"]}
    weights_meta = {tag: write_weight_blob(tag, params)
                    for tag, params in weight_sets.items()}
    log(f"[aot] wrote {len(weights_meta)} weight blobs")

    ex = Exporter()
    from .configs import bert_variants, gpt2_variants
    export_model(ex, VIT, vit_variants(), [EVAL_B, LAT_B], log)
    export_model(ex, BERT, bert_variants(), [EVAL_B], log)
    export_model(ex, GPT2, gpt2_variants(), [EVAL_B, LAT_B], log)

    export_datasets(log)
    export_fixtures(weight_sets, log)

    manifest = {
        "format": 1,
        "models": {name: {
            "name": name, "kind": cfg.kind, "n": cfg.n, "d": cfg.d,
            "heads": cfg.heads, "layers": cfg.layers, "ffn": cfg.ffn,
            "vocab": cfg.vocab, "img": cfg.img, "patch": cfg.patch,
            "causal": cfg.causal,
        } for name, cfg in MODELS.items()},
        "weights": weights_meta,
        "executables": ex.entries,
        "variants": [variant_record(MODELS[v.model], v)
                     for v in all_variants()],
        "eval_batch": EVAL_B,
        "latency_batch": LAT_B,
    }
    with open(os.path.join(ART, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"[aot] manifest: {len(ex.entries)} executables")


if __name__ == "__main__":
    argparse.ArgumentParser(description=__doc__).parse_args()
    main()
