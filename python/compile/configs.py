"""Shared configuration for models, partitioning variants, and AOT export.

This module is the single source of truth for every shape that crosses the
python -> rust boundary. ``aot.py`` serializes the relevant parts to
``artifacts/config.json`` so the rust coordinator never re-derives a shape
independently (it *does* re-derive partition plans, and tests assert both
sides agree).

Paper mapping (PRISM, Qazi et al. 2025):
  * partitioning  -> Algorithm 1 (sequence split, last partition takes the
    remainder)
  * segment plan  -> Algorithm 2 + Eq. 16 (L = floor(N / (CR * P)))
  * PDPLC         -> per-device per-layer communication in tokens,
    (P-1) * L for PRISM, (P-1) * floor(N/P) for Voltage
"""

from __future__ import annotations

import dataclasses
from typing import Optional


# Batch sizes baked into the AOT executables. ``EVAL_B`` amortizes
# throughput-style evaluation; ``LAT_B`` is the paper's Fig. 5 single-query
# latency setting (batch size 1).
EVAL_B = 16
LAT_B = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of one tiny Transformer used in the reproduction."""

    name: str              # "vit" | "bert" | "gpt2"
    kind: str              # "encoder" | "decoder"
    n: int                 # sequence length N (tokens incl. CLS for encoders)
    d: int                 # embedding dim D
    heads: int             # attention heads H (head dim = D // H)
    layers: int            # Transformer blocks
    ffn_mult: int = 4      # FFN hidden = ffn_mult * D
    vocab: int = 0         # token vocabulary (0 => image model)
    img: int = 0           # image side (vision models)
    patch: int = 0         # patch side (vision models)
    causal: bool = False   # partition-aware causal mask (decoder models)

    @property
    def dh(self) -> int:
        assert self.d % self.heads == 0
        return self.d // self.heads

    @property
    def ffn(self) -> int:
        return self.ffn_mult * self.d


VIT = ModelConfig(name="vit", kind="encoder", n=65, d=128, heads=4, layers=4,
                  img=32, patch=4)
BERT = ModelConfig(name="bert", kind="encoder", n=64, d=128, heads=4,
                   layers=4, vocab=256)
GPT2 = ModelConfig(name="gpt2", kind="decoder", n=128, d=128, heads=4,
                   layers=4, vocab=96, causal=True)

MODELS = {m.name: m for m in (VIT, BERT, GPT2)}


def partition_sizes(n: int, p: int) -> list[int]:
    """Algorithm 1: split N tokens into P contiguous partitions.

    Every partition gets floor(N/P) tokens; the last one also takes the
    remainder, exactly as in the paper's Algorithm 1.
    """
    if p <= 0 or n < p:
        raise ValueError(f"invalid partitioning N={n} P={p}")
    s, r = divmod(n, p)
    return [s] * (p - 1) + [s + r]


def segment_counts(n_p: int, l: int) -> list[int]:
    """Algorithm 2: per-segment token counts for one partition.

    Segments 0..L-2 hold ``s = floor(N_p / L)`` tokens; the last segment
    holds ``s + (N_p mod L)``. The counts are what the scaling-aware softmax
    uses as its repetition vector ``g``.
    """
    if l <= 0 or n_p < l:
        raise ValueError(f"invalid segment plan N_p={n_p} L={l}")
    s, r = divmod(n_p, l)
    return [s] * (l - 1) + [s + r]


def landmarks_for_cr(n: int, p: int, cr: float) -> int:
    """Eq. 16: L = floor(N / (CR * P)), clamped to >= 1."""
    return max(1, int(n / (cr * p)))


def effective_cr(n: int, p: int, l: int) -> float:
    """Actual compression rate achieved by L landmarks: CR = N / (L * P)."""
    return n / (l * p)


def pdplc_prism(p: int, l: int) -> int:
    """Per-device per-layer communication, in tokens (PRISM)."""
    return (p - 1) * l


def pdplc_voltage(n: int, p: int) -> int:
    """Per-device per-layer communication, in tokens (Voltage baseline)."""
    return (p - 1) * (n // p)


@dataclasses.dataclass(frozen=True)
class Variant:
    """One distributed-inference configuration to AOT-compile.

    ``mode`` is one of:
      * "single"  — P = 1 baseline, full attention on one device
      * "voltage" — position-wise partitioning with full AllGather [20]
      * "prism"   — the paper's system (segment means + scaling-aware attn)
    """

    model: str
    mode: str
    p: int = 1
    l: int = 0             # landmarks per partition (prism only)

    def key(self) -> str:
        if self.mode == "single":
            return f"{self.model}_single"
        if self.mode == "voltage":
            return f"{self.model}_voltage_p{self.p}"
        return f"{self.model}_prism_p{self.p}l{self.l}"

    def cr(self) -> Optional[float]:
        if self.mode != "prism":
            return None
        return effective_cr(MODELS[self.model].n, self.p, self.l)


def vit_variants() -> list[Variant]:
    """Table IV rows (plus Table II / Fig. 4 points) for the ViT model."""
    vs = [Variant("vit", "single")]
    vs += [Variant("vit", "voltage", p) for p in (2, 3)]
    # P=2: L in {3, 6, 10}  -> CR in {10.8, 5.4, 3.25}  (paper: 9.9/4.95/3.3)
    vs += [Variant("vit", "prism", 2, l) for l in (3, 6, 10)]
    # P=3: L in {3, 5, 10}  -> CR in {7.2, 4.3, 2.2}    (paper: 6.55/3.28/2.18)
    vs += [Variant("vit", "prism", 3, l) for l in (3, 5, 10)]
    return vs


def bert_variants() -> list[Variant]:
    """Table V rows for the BERT model."""
    vs = [Variant("bert", "single")]
    vs += [Variant("bert", "voltage", p) for p in (2, 3)]
    # P=2: L=3 (CR~10.7, paper CR=9.5) and L=1 (max compression, paper CR=128)
    vs += [Variant("bert", "prism", 2, l) for l in (3, 1)]
    # P=3: L=2 (CR~10.7) and L=1 (CR~21.3, paper CR=85.5)
    vs += [Variant("bert", "prism", 3, l) for l in (2, 1)]
    return vs


GPT2_CRS = list(range(2, 11))  # Table VI sweeps CR = 2..10


def gpt2_variants() -> list[Variant]:
    """Table VI rows for the GPT-2 model (CR = 2..10, P in {2, 3})."""
    vs = [Variant("gpt2", "single")]
    vs += [Variant("gpt2", "voltage", p) for p in (2, 3)]
    seen = set()
    for p in (2, 3):
        for cr in GPT2_CRS:
            l = landmarks_for_cr(GPT2.n, p, cr)
            if (p, l) not in seen:
                seen.add((p, l))
                vs.append(Variant("gpt2", "prism", p, l))
    return vs


def all_variants() -> list[Variant]:
    return vit_variants() + bert_variants() + gpt2_variants()


# Datasets -> (model, head name, number of classes / output dim).
VIT_DATASETS = {
    # CIFAR-10 / CIFAR-100 / ImageNet-1K stand-ins (see DESIGN.md).
    "synth10": 10,
    "synth100": 100,
    "synthhard": 100,
}

# GLUE stand-ins: task -> (classes, metric). Regression tasks use classes=1.
BERT_TASKS = {
    "sst2p": (2, "acc"),
    "mnlip": (3, "acc"),
    "qnlip": (2, "acc"),
    "rtep": (2, "acc"),
    "mrpcp": (2, "f1"),
    "qqpp": (2, "f1"),
    "colap": (2, "mcc"),
    "stsbp": (1, "spearman"),
}
