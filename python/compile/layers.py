"""Layer-2 building blocks: layernorm, FFN, embeddings, parameter init.

Parameter layout is the python ↔ rust contract: ``BLOCK_TENSORS`` /
``*_EMBED_TENSORS`` / ``HEAD_TENSORS`` fix both the order in which tensors
are flattened into ``weights.bin`` and the order in which the AOT block
executables expect them as inputs (weights first, then data arguments).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels.ref import gelu_ref, layernorm_ref

# Per-block tensors in flattening/executable-input order.
# Shapes as functions of the model config.
BLOCK_TENSORS = [
    ("ln1_g", lambda c: (c.d,)),
    ("ln1_b", lambda c: (c.d,)),
    ("wq", lambda c: (c.d, c.d)),
    ("bq", lambda c: (c.d,)),
    ("wk", lambda c: (c.d, c.d)),
    ("bk", lambda c: (c.d,)),
    ("wv", lambda c: (c.d, c.d)),
    ("bv", lambda c: (c.d,)),
    ("wo", lambda c: (c.d, c.d)),
    ("bo", lambda c: (c.d,)),
    ("ln2_g", lambda c: (c.d,)),
    ("ln2_b", lambda c: (c.d,)),
    ("w1", lambda c: (c.d, c.ffn)),
    ("b1", lambda c: (c.ffn,)),
    ("w2", lambda c: (c.ffn, c.d)),
    ("b2", lambda c: (c.d,)),
]

VIT_EMBED_TENSORS = [
    ("patch_w", lambda c: (c.patch * c.patch * 3, c.d)),
    ("patch_b", lambda c: (c.d,)),
    ("cls", lambda c: (c.d,)),
    ("pos", lambda c: (c.n, c.d)),
]

TOK_EMBED_TENSORS = [
    ("tok", lambda c: (c.vocab, c.d)),
    ("pos", lambda c: (c.n, c.d)),
]

# Head output dim is task-dependent -> shape fns take (cfg, classes).
HEAD_TENSORS = [
    ("ln_g", lambda c, k: (c.d,)),
    ("ln_b", lambda c, k: (c.d,)),
    ("w", lambda c, k: (c.d, k)),
    ("b", lambda c, k: (k,)),
]


def embed_tensors(cfg: ModelConfig):
    return VIT_EMBED_TENSORS if cfg.img else TOK_EMBED_TENSORS


def _init_tensor(key, name: str, shape) -> jnp.ndarray:
    if name.endswith(("_g",)) or name == "ln_g":
        return jnp.ones(shape, jnp.float32)
    if name.endswith(("_b",)) or name in ("bq", "bk", "bv", "bo", "b1",
                                          "b2", "b", "patch_b", "cls"):
        return jnp.zeros(shape, jnp.float32)
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    scale = 0.02 if name in ("tok", "pos") else 1.0 / np.sqrt(fan_in)
    return scale * jax.random.normal(key, shape, jnp.float32)


def init_block(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, len(BLOCK_TENSORS))
    return {n: _init_tensor(k, n, fn(cfg))
            for k, (n, fn) in zip(keys, BLOCK_TENSORS)}


def init_embed(key, cfg: ModelConfig) -> dict:
    ts = embed_tensors(cfg)
    keys = jax.random.split(key, len(ts))
    return {n: _init_tensor(k, n, fn(cfg)) for k, (n, fn) in zip(keys, ts)}


def init_head(key, cfg: ModelConfig, classes: int) -> dict:
    keys = jax.random.split(key, len(HEAD_TENSORS))
    return {n: _init_tensor(k, n, fn(cfg, classes))
            for k, (n, fn) in zip(keys, HEAD_TENSORS)}


def ffn(blk: dict, x):
    return gelu_ref(x @ blk["w1"] + blk["b1"]) @ blk["w2"] + blk["b2"]


def ln1(blk: dict, x):
    return layernorm_ref(x, blk["ln1_g"], blk["ln1_b"])


def ln2(blk: dict, x):
    return layernorm_ref(x, blk["ln2_g"], blk["ln2_b"])


def embed_images(emb: dict, cfg: ModelConfig, imgs):
    """(B, img, img, 3) float32 -> (B, N, D): patchify + linear + CLS + pos."""
    b = imgs.shape[0]
    p, side = cfg.patch, cfg.img // cfg.patch
    x = imgs.reshape(b, side, p, side, p, 3)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(b, side * side, p * p * 3)
    x = x @ emb["patch_w"] + emb["patch_b"]
    cls = jnp.broadcast_to(emb["cls"][None, None, :], (b, 1, cfg.d))
    return jnp.concatenate([cls, x], axis=1) + emb["pos"][None]


def embed_tokens(emb: dict, cfg: ModelConfig, ids):
    """(B, N) int32 -> (B, N, D): lookup + learned positions."""
    return jnp.take(emb["tok"], ids, axis=0) + emb["pos"][None]


def head_apply(head: dict, cfg: ModelConfig, x, *, pool: str):
    """Final layernorm + linear head.

    pool = "cls": classify from token 0 (encoders).
    pool = "all": per-position logits (decoder LM).
    """
    h = layernorm_ref(x, head["ln_g"], head["ln_b"])
    if pool == "cls":
        h = h[:, 0, :]
    return h @ head["w"] + head["b"]
