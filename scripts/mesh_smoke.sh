#!/usr/bin/env bash
# mesh-smoke: the multi-process elastic serving acceptance.
#
# Spawns 3 real `prism worker --listen` processes and a master
# `prism serve --workers` on localhost, kills one worker mid-run, and
# asserts the run completes on P'=2 with exit 0 — the cross-process
# analogue of tests/integration.rs's
# server_repartitions_to_p2_on_one_of_three_worker_loss (same vit
# P=3 L=3 geometry, whose P'=2 fallback is in the AOT grid).
#
# Wired as `make mesh-smoke` and the CI mesh-smoke job. Skips cleanly
# (exit 0) when the AOT artifacts are absent, like every artifact-gated
# test in the repo.
set -u

cd "$(dirname "$0")/.."
ART="${PRISM_ARTIFACTS:-artifacts}"
if [ ! -f "$ART/manifest.json" ]; then
    echo "mesh-smoke: SKIP (no artifacts; run \`make artifacts\` first)"
    exit 0
fi

cargo build --release || exit 1
BIN=target/release/prism
PORTS=(47970 47971 47972)
LOG=$(mktemp -d)
echo "mesh-smoke: logs under $LOG"

WPIDS=()
SPID=""
cleanup() {
    kill ${WPIDS[@]+"${WPIDS[@]}"} ${SPID:+"$SPID"} 2>/dev/null
    wait 2>/dev/null
}
trap cleanup EXIT
for port in "${PORTS[@]}"; do
    "$BIN" worker --listen "127.0.0.1:$port" --artifacts "$ART" \
        >"$LOG/worker_$port.log" 2>&1 &
    WPIDS+=("$!")
done

WORKERS="127.0.0.1:${PORTS[0]},127.0.0.1:${PORTS[1]},127.0.0.1:${PORTS[2]}"
"$BIN" serve --model vit --dataset synth10 --mode prism --l 3 \
    --requests 96 --workers "$WORKERS" --gather-timeout-ms 3000 \
    --artifacts "$ART" >"$LOG/serve.log" 2>&1 &
SPID=$!

# grep_wait <pattern> <file> <seconds>
grep_wait() {
    for _ in $(seq 1 $(( $3 * 2 ))); do
        grep -q "$1" "$2" 2>/dev/null && return 0
        kill -0 "$SPID" 2>/dev/null || return 1
        sleep 0.5
    done
    return 1
}

if ! grep_wait "mesh up: 3 workers" "$LOG/serve.log" 120; then
    echo "mesh-smoke: FAIL (mesh never came up)"
    cat "$LOG/serve.log"
    exit 1
fi
if ! grep_wait "batch 1 done" "$LOG/serve.log" 300; then
    echo "mesh-smoke: FAIL (no batch completed on the full mesh)"
    cat "$LOG/serve.log"
    exit 1
fi

# kill one worker mid-run: the master must probe, re-plan to P'=2, and
# finish every remaining batch
kill "${WPIDS[1]}"
echo "mesh-smoke: killed worker on port ${PORTS[1]}"

wait "$SPID"
RC=$?
echo "--- serve.log ---"
cat "$LOG/serve.log"
if [ "$RC" -ne 0 ]; then
    echo "mesh-smoke: FAIL (serve exited $RC)"
    exit 1
fi
if ! grep -q "re-plans" "$LOG/serve.log"; then
    echo "mesh-smoke: FAIL (worker loss never re-planned)"
    exit 1
fi
if ! grep -q "done on epoch [1-9].*P'=2" "$LOG/serve.log"; then
    echo "mesh-smoke: FAIL (no batch completed on the P'=2 epoch)"
    exit 1
fi
if ! grep -q "throughput" "$LOG/serve.log"; then
    echo "mesh-smoke: FAIL (serve never reported completion)"
    exit 1
fi
echo "mesh-smoke: OK (worker killed mid-run, completed on P'=2, exit 0)"
